//! A work-stealing-free thread pool (offline substitute for `rayon`), used
//! by the coordinator's row-sweep scheduler.
//!
//! Two primitives:
//!
//! * [`ThreadPool::submit`] / [`ThreadPool::wait_idle`] — fire-and-forget
//!   `'static` tasks on persistent worker threads (a mutex+condvar injector
//!   queue). Worker threads wrap each task in `catch_unwind`, so a
//!   panicking task can neither kill a worker nor wedge `wait_idle`; the
//!   panic count is available via [`ThreadPool::panicked_tasks`].
//! * [`ThreadPool::for_chunks`] — a plain parallel-for: split `0..n` into
//!   chunks and run a borrowed closure per chunk, blocking until all
//!   complete. Built on `std::thread::scope`, which (a) lets the closure
//!   borrow from the caller's stack *safely* (no lifetime transmutes — the
//!   scope guarantees the threads are joined before the borrow ends) and
//!   (b) propagates a panic from any chunk to the caller instead of
//!   deadlocking a completion counter. Chunks are handed out through a
//!   shared atomic cursor, so at most [`ThreadPool::threads`] chunks run
//!   concurrently and early-finishing workers pick up the remaining ones
//!   (the paper's dynamic row-sweep scheduling, §3.2.2).
//! * [`ThreadPool::for_chunk_slices`] — the ownership-passing variant the
//!   kernel scheduler uses: the caller brings a `&mut [T]` of per-task
//!   items (e.g. disjoint tensor views) and each chunk worker receives an
//!   **exclusive `&mut` sub-slice** of it, carved with `split_at_mut`
//!   before any thread starts. Exclusivity is enforced by the borrow
//!   checker — no `unsafe`, no aliased `&mut`, nothing for Miri to object
//!   to. Same cursor-based dynamic chunk assignment and panic propagation
//!   as [`ThreadPool::for_chunks`].
//! * [`ThreadPool::for_chunk_slices_with`] — the same, plus a per-worker
//!   state value (`init()` once per participating thread, `&mut S` into
//!   every chunk that worker runs): the zero-alloc-hot-path hook the kernel
//!   scheduler uses to hand each worker one reusable scratch accumulator.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Task = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<std::collections::VecDeque<Task>>,
    cv: Condvar,
    shutdown: AtomicBool,
    /// Tasks submitted but not yet finished (for `wait_idle`).
    inflight: AtomicUsize,
    /// Submitted tasks that panicked (they still count as finished).
    panicked: AtomicUsize,
    idle_cv: Condvar,
    idle_mx: Mutex<()>,
}

/// Fixed-size thread pool. Persistent workers are spawned lazily on the
/// first [`ThreadPool::submit`]: the `for_chunks` path uses scoped threads
/// instead, so schedulers that never submit fire-and-forget work don't
/// hold idle OS threads parked on the queue condvar.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Mutex<Vec<JoinHandle<()>>>,
    n_threads: usize,
}

impl ThreadPool {
    /// Create a pool that will use `n` worker threads (`n >= 1`).
    pub fn new(n: usize) -> ThreadPool {
        let n = n.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(std::collections::VecDeque::new()),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            inflight: AtomicUsize::new(0),
            panicked: AtomicUsize::new(0),
            idle_cv: Condvar::new(),
            idle_mx: Mutex::new(()),
        });
        ThreadPool { shared, workers: Mutex::new(Vec::new()), n_threads: n }
    }

    /// Spawn the persistent workers if they are not running yet.
    fn ensure_workers(&self) {
        let mut workers = self.workers.lock().unwrap();
        if !workers.is_empty() {
            return;
        }
        for i in 0..self.n_threads {
            let sh = Arc::clone(&self.shared);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("sparsetrain-worker-{i}"))
                    .spawn(move || worker_loop(sh))
                    .expect("spawn worker"),
            );
        }
    }

    /// Pool sized to available host parallelism.
    pub fn with_host_parallelism() -> ThreadPool {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        ThreadPool::new(n)
    }

    pub fn threads(&self) -> usize {
        self.n_threads
    }

    /// Submit a fire-and-forget task (spawns the persistent workers on
    /// first use).
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.ensure_workers();
        self.shared.inflight.fetch_add(1, Ordering::SeqCst);
        let mut q = self.shared.queue.lock().unwrap();
        q.push_back(Box::new(f));
        self.shared.cv.notify_one();
    }

    /// Block until every submitted task has finished (panicked tasks count
    /// as finished — see [`ThreadPool::panicked_tasks`]).
    pub fn wait_idle(&self) {
        let mut guard = self.shared.idle_mx.lock().unwrap();
        while self.shared.inflight.load(Ordering::SeqCst) != 0 {
            guard = self.shared.idle_cv.wait(guard).unwrap();
        }
    }

    /// Number of submitted tasks that panicked since pool creation.
    pub fn panicked_tasks(&self) -> usize {
        self.shared.panicked.load(Ordering::SeqCst)
    }

    /// Parallel-for over `0..n` in up to `chunks` contiguous chunks.
    /// `f(chunk_idx, start, end)` runs on up to [`ThreadPool::threads`]
    /// threads (the calling thread participates); blocks until all chunks
    /// finish. `f` must be `Sync` because multiple workers call it
    /// concurrently.
    ///
    /// A panic inside `f` is propagated to the caller once every other
    /// in-flight chunk has finished — callers observe the original panic
    /// payload instead of a deadlock, and the pool stays usable.
    pub fn for_chunks<F>(&self, n: usize, chunks: usize, f: F)
    where
        F: Fn(usize, usize, usize) + Send + Sync,
    {
        if n == 0 {
            return;
        }
        let chunks = chunks.clamp(1, n);
        let chunk_len = n.div_ceil(chunks);
        // Number of non-empty chunks actually dispatched.
        let n_chunks = n.div_ceil(chunk_len);
        let workers = self.n_threads.min(n_chunks);
        let cursor = AtomicUsize::new(0);

        let run_chunks = |cursor: &AtomicUsize, f: &F| loop {
            let ci = cursor.fetch_add(1, Ordering::Relaxed);
            if ci >= n_chunks {
                break;
            }
            let start = ci * chunk_len;
            let end = (start + chunk_len).min(n);
            f(ci, start, end);
        };

        // `scope` joins every spawned thread before returning, which makes
        // borrowing `f` and `cursor` from this stack frame sound, and
        // resumes the panic of any panicked chunk in the caller.
        std::thread::scope(|s| {
            for _ in 1..workers {
                s.spawn(|| run_chunks(&cursor, &f));
            }
            run_chunks(&cursor, &f);
        });
    }

    /// Parallel-for over a slice of per-task items, handing each chunk
    /// worker an **exclusive** `&mut` sub-slice of `items`.
    ///
    /// `f(chunk_idx, start, chunk_items)` runs once per non-empty chunk;
    /// `start` is the index of `chunk_items[0]` within `items`. The
    /// sub-slices are produced by repeated `split_at_mut` *before* any
    /// worker starts, so every `&mut [T]` a worker sees is disjoint by
    /// construction and checked by the compiler — this is the primitive
    /// that lets the kernel scheduler pass owned tensor views into tasks
    /// without any `unsafe` pointer sharing.
    ///
    /// Chunk → worker assignment is dynamic (shared atomic cursor), so
    /// early-finishing workers pick up remaining chunks. A panic inside
    /// `f` propagates to the caller once the scope joins, and the pool
    /// stays usable afterwards.
    pub fn for_chunk_slices<T, F>(&self, items: &mut [T], chunks: usize, f: F)
    where
        T: Send,
        F: Fn(usize, usize, &mut [T]) + Send + Sync,
    {
        self.for_chunk_slices_with(items, chunks, || (), |ci, start, chunk, _| f(ci, start, chunk));
    }

    /// [`ThreadPool::for_chunk_slices`] with **per-worker state**: each
    /// participating worker thread calls `init()` exactly once before
    /// claiming chunks and passes the resulting `&mut S` to every chunk it
    /// runs. This is how the kernel scheduler gives each worker one
    /// reusable [`crate::kernels::Scratch`] accumulator — tasks stop
    /// allocating per-task buffers while the state never crosses threads
    /// (so `S` needs no `Send`/`Sync`).
    ///
    /// Same chunk carving, dynamic cursor assignment and panic propagation
    /// as [`ThreadPool::for_chunk_slices`].
    pub fn for_chunk_slices_with<T, S, I, F>(&self, items: &mut [T], chunks: usize, init: I, f: F)
    where
        T: Send,
        I: Fn() -> S + Send + Sync,
        F: Fn(usize, usize, &mut [T], &mut S) + Send + Sync,
    {
        let n = items.len();
        if n == 0 {
            return;
        }
        let chunks = chunks.clamp(1, n);
        let chunk_len = n.div_ceil(chunks);
        // Carve `items` into disjoint sub-slices up front. Each slot is
        // taken exactly once (by whichever worker claims that chunk index
        // from the cursor); the Mutex<Option<..>> is only the hand-off
        // cell, not a lock anything contends on.
        let parts: Vec<Mutex<Option<(usize, &mut [T])>>> = items
            .chunks_mut(chunk_len)
            .enumerate()
            .map(|(i, chunk)| Mutex::new(Some((i * chunk_len, chunk))))
            .collect();
        let n_chunks = parts.len();
        let workers = self.n_threads.min(n_chunks);
        let cursor = AtomicUsize::new(0);

        let run_chunks = |cursor: &AtomicUsize, init: &I, f: &F| {
            let mut state = init();
            loop {
                let ci = cursor.fetch_add(1, Ordering::Relaxed);
                if ci >= n_chunks {
                    break;
                }
                let (chunk_start, chunk_items) =
                    parts[ci].lock().unwrap().take().expect("chunk claimed exactly once");
                f(ci, chunk_start, chunk_items, &mut state);
            }
        };

        std::thread::scope(|s| {
            for _ in 1..workers {
                s.spawn(|| run_chunks(&cursor, &init, &f));
            }
            run_chunks(&cursor, &init, &f);
        });
    }
}

fn worker_loop(sh: Arc<Shared>) {
    loop {
        let task = {
            let mut q = sh.queue.lock().unwrap();
            loop {
                if let Some(t) = q.pop_front() {
                    break t;
                }
                if sh.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                q = sh.cv.wait(q).unwrap();
            }
        };
        // A panicking task must not kill the worker or leak an inflight
        // count (which would deadlock `wait_idle` forever).
        if catch_unwind(AssertUnwindSafe(task)).is_err() {
            sh.panicked.fetch_add(1, Ordering::SeqCst);
        }
        if sh.inflight.fetch_sub(1, Ordering::SeqCst) == 1 {
            let _g = sh.idle_mx.lock().unwrap();
            sh.idle_cv.notify_all();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
        for w in self.workers.lock().unwrap().drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_tasks() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn for_chunks_covers_range_exactly_once() {
        let pool = ThreadPool::new(3);
        let n = 1013;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        pool.for_chunks(n, 8, |_ci, s, e| {
            for i in s..e {
                hits[i].fetch_add(1, Ordering::SeqCst);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn for_chunks_handles_more_chunks_than_items() {
        let pool = ThreadPool::new(2);
        let n = 3;
        let sum = AtomicU64::new(0);
        pool.for_chunks(n, 16, |_ci, s, e| {
            for i in s..e {
                sum.fetch_add(i as u64, Ordering::SeqCst);
            }
        });
        assert_eq!(sum.load(Ordering::SeqCst), 3); // 0 + 1 + 2
    }

    #[test]
    fn for_chunks_empty_range() {
        let pool = ThreadPool::new(2);
        pool.for_chunks(0, 4, |_, _, _| panic!("must not run"));
    }

    #[test]
    fn for_chunks_single_thread_runs_inline() {
        let pool = ThreadPool::new(1);
        let caller = std::thread::current().id();
        let same_thread = AtomicU64::new(1);
        pool.for_chunks(10, 4, |_, _, _| {
            if std::thread::current().id() != caller {
                same_thread.store(0, Ordering::SeqCst);
            }
        });
        assert_eq!(same_thread.load(Ordering::SeqCst), 1);
    }

    /// Regression: a panicking chunk used to leave the completion counter
    /// short, blocking the caller forever. Now the panic propagates and
    /// the pool survives.
    #[test]
    fn for_chunks_panic_propagates_instead_of_deadlocking() {
        let pool = ThreadPool::new(4);
        let result = catch_unwind(AssertUnwindSafe(|| {
            pool.for_chunks(100, 8, |_ci, s, _e| {
                if s == 0 {
                    panic!("task boom");
                }
            });
        }));
        assert!(result.is_err(), "panic must reach the caller");

        // The pool is fully usable afterwards.
        let sum = AtomicU64::new(0);
        pool.for_chunks(10, 4, |_ci, s, e| {
            for i in s..e {
                sum.fetch_add(i as u64, Ordering::SeqCst);
            }
        });
        assert_eq!(sum.load(Ordering::SeqCst), 45);
    }

    #[test]
    fn for_chunk_slices_visits_every_item_exactly_once() {
        let pool = ThreadPool::new(3);
        let mut items: Vec<u64> = vec![0; 1013];
        pool.for_chunk_slices(&mut items, 8, |_ci, start, chunk| {
            for (off, item) in chunk.iter_mut().enumerate() {
                // record which index the worker believes it owns
                *item += (start + off) as u64 + 1;
            }
        });
        for (i, item) in items.iter().enumerate() {
            assert_eq!(*item, i as u64 + 1, "item {i} visited wrong number of times");
        }
    }

    /// Per-worker state: `init` runs at most once per participating
    /// thread, the state is reused across every chunk that worker claims,
    /// and all items are still visited exactly once.
    #[test]
    fn for_chunk_slices_with_reuses_worker_state() {
        let pool = ThreadPool::new(3);
        let inits = AtomicU64::new(0);
        let mut items: Vec<u64> = vec![0; 257];
        pool.for_chunk_slices_with(
            &mut items,
            12,
            || {
                inits.fetch_add(1, Ordering::SeqCst);
                // per-worker chunk counter, never shared across threads
                0u64
            },
            |_ci, _start, chunk, state| {
                *state += 1;
                for item in chunk.iter_mut() {
                    *item += *state; // nonzero: state survives across chunks
                }
            },
        );
        let n_inits = inits.load(Ordering::SeqCst);
        assert!((1..=3).contains(&n_inits), "one init per worker, got {n_inits}");
        assert!(items.iter().all(|&v| v >= 1), "every item visited with live state");
    }

    #[test]
    fn for_chunk_slices_empty_and_oversubscribed() {
        let pool = ThreadPool::new(4);
        let mut empty: Vec<u32> = Vec::new();
        pool.for_chunk_slices(&mut empty, 8, |_, _, _| panic!("must not run"));

        let mut small = vec![0u32; 3];
        pool.for_chunk_slices(&mut small, 16, |_ci, _start, chunk| {
            for item in chunk.iter_mut() {
                *item += 1;
            }
        });
        assert_eq!(small, vec![1, 1, 1]);
    }

    /// Stress test (ISSUE 2 satellite): a task that panics mid-chunk must
    /// propagate the panic to the caller — no deadlock, no poisoned pool —
    /// under *repeated* invocations of both parallel-for primitives. This
    /// is regression cover for the PR 1 `std::thread::scope` rebuild: the
    /// pre-rebuild completion-counter design deadlocked on the first
    /// panicking chunk and the old pool was unusable afterwards.
    #[test]
    fn repeated_panics_propagate_without_poisoning_the_pool() {
        let pool = ThreadPool::new(4);
        let rounds: usize = if cfg!(miri) { 3 } else { 20 };
        for round in 0..rounds {
            // for_chunks: panic in a different chunk each round.
            let boom = (round * 13) % 100;
            let result = catch_unwind(AssertUnwindSafe(|| {
                pool.for_chunks(100, 8, |_ci, s, e| {
                    if (s..e).contains(&boom) {
                        panic!("for_chunks boom round {round}");
                    }
                });
            }));
            assert!(result.is_err(), "round {round}: panic must reach the caller");

            // for_chunk_slices: same, through the ownership-passing path.
            let mut items = vec![0u8; 64];
            let result = catch_unwind(AssertUnwindSafe(|| {
                pool.for_chunk_slices(&mut items, 8, |_ci, start, chunk| {
                    if (start..start + chunk.len()).contains(&(boom % 64)) {
                        panic!("for_chunk_slices boom round {round}");
                    }
                    for item in chunk.iter_mut() {
                        *item = 1;
                    }
                });
            }));
            assert!(result.is_err(), "round {round}: slice panic must reach the caller");

            // The pool must stay fully usable between panicking rounds.
            let sum = AtomicU64::new(0);
            pool.for_chunks(10, 4, |_ci, s, e| {
                for i in s..e {
                    sum.fetch_add(i as u64, Ordering::SeqCst);
                }
            });
            assert_eq!(sum.load(Ordering::SeqCst), 45, "round {round}: pool wedged");

            let mut ok = vec![0u64; 32];
            pool.for_chunk_slices(&mut ok, 4, |_ci, _start, chunk| {
                for item in chunk.iter_mut() {
                    *item += 1;
                }
            });
            assert!(ok.iter().all(|&v| v == 1), "round {round}: slice pool wedged");
        }
    }

    /// Regression: a panicking submitted task must not wedge `wait_idle`
    /// or kill the worker thread.
    #[test]
    fn submit_panic_does_not_wedge_wait_idle() {
        let pool = ThreadPool::new(2);
        let c = Arc::new(AtomicU64::new(0));
        pool.submit(|| panic!("submitted boom"));
        for _ in 0..10 {
            let c = Arc::clone(&c);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(c.load(Ordering::SeqCst), 10);
        assert_eq!(pool.panicked_tasks(), 1);
    }

    #[test]
    fn for_chunks_needs_no_persistent_workers() {
        let pool = ThreadPool::new(4);
        pool.for_chunks(100, 8, |_, _, _| {});
        assert!(pool.workers.lock().unwrap().is_empty(), "scoped path must not spawn workers");
        pool.submit(|| {});
        pool.wait_idle();
        assert_eq!(pool.workers.lock().unwrap().len(), 4, "submit spawns the full worker set");
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(2);
        let c = Arc::new(AtomicU64::new(0));
        for _ in 0..10 {
            let c = Arc::clone(&c);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        drop(pool);
        assert_eq!(c.load(Ordering::SeqCst), 10);
    }
}
