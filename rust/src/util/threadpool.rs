//! A work-stealing-free, channel-based thread pool (offline substitute for
//! `rayon`), used by the coordinator's row-sweep scheduler.
//!
//! Design: a shared injector queue guarded by a mutex + condvar. Tasks are
//! boxed closures. `scope_chunks` provides the parallel-for primitive the
//! scheduler needs: split an index range into chunks and run a worker
//! closure per chunk, blocking until every chunk completes.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Task = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<std::collections::VecDeque<Task>>,
    cv: Condvar,
    shutdown: AtomicBool,
    /// Tasks submitted but not yet finished (for `wait_idle`).
    inflight: AtomicUsize,
    idle_cv: Condvar,
    idle_mx: Mutex<()>,
}

/// Fixed-size thread pool.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    n_threads: usize,
}

impl ThreadPool {
    /// Create a pool with `n` worker threads (`n >= 1`).
    pub fn new(n: usize) -> ThreadPool {
        let n = n.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(std::collections::VecDeque::new()),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            inflight: AtomicUsize::new(0),
            idle_cv: Condvar::new(),
            idle_mx: Mutex::new(()),
        });
        let workers = (0..n)
            .map(|i| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("sparsetrain-worker-{i}"))
                    .spawn(move || worker_loop(sh))
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { shared, workers, n_threads: n }
    }

    /// Pool sized to available host parallelism.
    pub fn with_host_parallelism() -> ThreadPool {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        ThreadPool::new(n)
    }

    pub fn threads(&self) -> usize {
        self.n_threads
    }

    /// Submit a fire-and-forget task.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.shared.inflight.fetch_add(1, Ordering::SeqCst);
        let mut q = self.shared.queue.lock().unwrap();
        q.push_back(Box::new(f));
        self.shared.cv.notify_one();
    }

    /// Block until every submitted task has finished.
    pub fn wait_idle(&self) {
        let mut guard = self.shared.idle_mx.lock().unwrap();
        while self.shared.inflight.load(Ordering::SeqCst) != 0 {
            guard = self.shared.idle_cv.wait(guard).unwrap();
        }
    }

    /// Parallel-for over `0..n` in `chunks` contiguous chunks. `f(chunk_idx,
    /// start, end)` runs on pool threads; blocks until all chunks finish.
    ///
    /// `f` must be `Sync` because multiple workers call it concurrently.
    pub fn for_chunks<F>(&self, n: usize, chunks: usize, f: F)
    where
        F: Fn(usize, usize, usize) + Send + Sync,
    {
        if n == 0 {
            return;
        }
        let chunks = chunks.clamp(1, n);
        let chunk_len = n.div_ceil(chunks);
        // SAFETY of lifetime: we block until all tasks complete before
        // returning, so borrowing f from the stack is sound. We enforce it
        // by transmuting through Arc<…'static> after a scope barrier.
        let done = Arc::new((Mutex::new(0usize), Condvar::new()));
        let f: Arc<dyn Fn(usize, usize, usize) + Send + Sync> = {
            // Extend lifetime: justified because of the completion barrier
            // below (no task outlives this call).
            let f_ref: &(dyn Fn(usize, usize, usize) + Send + Sync) = &f;
            let f_static: &'static (dyn Fn(usize, usize, usize) + Send + Sync) =
                unsafe { std::mem::transmute(f_ref) };
            Arc::from(f_static)
        };
        let mut launched = 0usize;
        for ci in 0..chunks {
            let start = ci * chunk_len;
            if start >= n {
                break;
            }
            let end = (start + chunk_len).min(n);
            let f = Arc::clone(&f);
            let done = Arc::clone(&done);
            launched += 1;
            self.submit(move || {
                f(ci, start, end);
                let (mx, cv) = &*done;
                *mx.lock().unwrap() += 1;
                cv.notify_one();
            });
        }
        let (mx, cv) = &*done;
        let mut finished = mx.lock().unwrap();
        while *finished < launched {
            finished = cv.wait(finished).unwrap();
        }
    }
}

fn worker_loop(sh: Arc<Shared>) {
    loop {
        let task = {
            let mut q = sh.queue.lock().unwrap();
            loop {
                if let Some(t) = q.pop_front() {
                    break t;
                }
                if sh.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                q = sh.cv.wait(q).unwrap();
            }
        };
        task();
        if sh.inflight.fetch_sub(1, Ordering::SeqCst) == 1 {
            let _g = sh.idle_mx.lock().unwrap();
            sh.idle_cv.notify_all();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_tasks() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn for_chunks_covers_range_exactly_once() {
        let pool = ThreadPool::new(3);
        let n = 1013;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        pool.for_chunks(n, 8, |_ci, s, e| {
            for i in s..e {
                hits[i].fetch_add(1, Ordering::SeqCst);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn for_chunks_handles_more_chunks_than_items() {
        let pool = ThreadPool::new(2);
        let n = 3;
        let sum = AtomicU64::new(0);
        pool.for_chunks(n, 16, |_ci, s, e| {
            for i in s..e {
                sum.fetch_add(i as u64, Ordering::SeqCst);
            }
        });
        assert_eq!(sum.load(Ordering::SeqCst), 0 + 1 + 2);
    }

    #[test]
    fn for_chunks_empty_range() {
        let pool = ThreadPool::new(2);
        pool.for_chunks(0, 4, |_, _, _| panic!("must not run"));
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(2);
        let c = Arc::new(AtomicU64::new(0));
        for _ in 0..10 {
            let c = Arc::clone(&c);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        drop(pool);
        assert_eq!(c.load(Ordering::SeqCst), 10);
    }
}
