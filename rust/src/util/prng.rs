//! Deterministic pseudo-random number generation (xorshift128+ / splitmix64).
//!
//! All experiments in this repo are seeded so every table and figure is
//! exactly reproducible run-to-run.

/// A small, fast, seedable PRNG (xorshift128+). Not cryptographic.
#[derive(Debug, Clone)]
pub struct Xorshift {
    s0: u64,
    s1: u64,
}

/// splitmix64 step — used to expand a single seed into xorshift state and as
/// a standalone mixing function.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Xorshift {
    /// Create a PRNG from a seed. Any seed (including 0) is valid.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s0 = splitmix64(&mut sm);
        let s1 = splitmix64(&mut sm);
        Xorshift { s0, s1 }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.s0;
        let y = self.s1;
        self.s0 = y;
        x ^= x << 23;
        self.s1 = x ^ y ^ (x >> 17) ^ (y >> 26);
        self.s1.wrapping_add(y)
    }

    /// Next u32.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform f32 in `[lo, hi)`.
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Uniform integer in `[0, n)`. `n` must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Multiply-shift; bias is negligible for our n (< 2^32).
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Bernoulli trial with probability `p` of `true`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard normal via Box–Muller (one value per call; simple, adequate).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = self.next_f64();
            if u > 1e-12 {
                let v = self.next_f64();
                return (-2.0 * u.ln()).sqrt() * (2.0 * std::f64::consts::PI * v).cos();
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Fork a child PRNG with a decorrelated stream (for parallel workers).
    pub fn fork(&mut self, stream: u64) -> Xorshift {
        let mut sm = self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15);
        let s0 = splitmix64(&mut sm);
        let s1 = splitmix64(&mut sm);
        Xorshift { s0, s1 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Xorshift::new(42);
        let mut b = Xorshift::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Xorshift::new(1);
        let mut b = Xorshift::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Xorshift::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Xorshift::new(9);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            let i = r.below(10);
            assert!(i < 10);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn bernoulli_rate_close() {
        let mut r = Xorshift::new(11);
        let n = 100_000;
        let hits = (0..n).filter(|_| r.bernoulli(0.3)).count();
        let rate = hits as f64 / n as f64;
        assert!((rate - 0.3).abs() < 0.01, "rate={rate}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Xorshift::new(13);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xorshift::new(17);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_decorrelates() {
        let mut r = Xorshift::new(21);
        let mut c1 = r.fork(0);
        let mut c2 = r.fork(1);
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert!(same < 2);
    }
}
