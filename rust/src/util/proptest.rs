//! A miniature property-based testing framework (offline substitute for
//! `proptest`), used for coordinator and kernel invariants.
//!
//! Features: seeded case generation, failure shrinking for integer-vector
//! inputs, and readable counterexample reports via panic messages.

use crate::util::prng::Xorshift;

/// Configuration for a property run.
#[derive(Debug, Clone, Copy)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    pub max_shrink_steps: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 128, seed: 0xC0FFEE, max_shrink_steps: 512 }
    }
}

/// A generator of values of type `T` from a PRNG.
pub trait Gen<T> {
    fn generate(&self, rng: &mut Xorshift) -> T;
    /// Candidate "smaller" versions of a failing value (one shrink step).
    fn shrink(&self, value: &T) -> Vec<T> {
        let _ = value;
        Vec::new()
    }
}

/// Uniform usize in `[lo, hi]` inclusive; shrinks toward `lo`.
pub struct UsizeIn {
    pub lo: usize,
    pub hi: usize,
}

impl Gen<usize> for UsizeIn {
    fn generate(&self, rng: &mut Xorshift) -> usize {
        self.lo + rng.below(self.hi - self.lo + 1)
    }
    fn shrink(&self, v: &usize) -> Vec<usize> {
        let mut out = Vec::new();
        if *v > self.lo {
            out.push(self.lo);
            out.push(self.lo + (*v - self.lo) / 2);
            out.push(*v - 1);
        }
        out.dedup();
        out
    }
}

/// f64 in `[lo, hi)`; shrinks toward lo and 0 (if representable in range).
pub struct F64In {
    pub lo: f64,
    pub hi: f64,
}

impl Gen<f64> for F64In {
    fn generate(&self, rng: &mut Xorshift) -> f64 {
        self.lo + (self.hi - self.lo) * rng.next_f64()
    }
    fn shrink(&self, v: &f64) -> Vec<f64> {
        let mut out = vec![self.lo];
        if self.lo <= 0.0 && 0.0 < self.hi && *v != 0.0 {
            out.push(0.0);
        }
        out.push(self.lo + (*v - self.lo) / 2.0);
        out.retain(|x| x != v);
        out
    }
}

/// Vector of usizes with length in `[min_len, max_len]`, elements from
/// `elem`. Shrinks by removing elements and shrinking single elements.
pub struct VecOfUsize {
    pub min_len: usize,
    pub max_len: usize,
    pub elem: UsizeIn,
}

impl Gen<Vec<usize>> for VecOfUsize {
    fn generate(&self, rng: &mut Xorshift) -> Vec<usize> {
        let len = self.min_len + rng.below(self.max_len - self.min_len + 1);
        (0..len).map(|_| self.elem.generate(rng)).collect()
    }
    fn shrink(&self, v: &Vec<usize>) -> Vec<Vec<usize>> {
        let mut out = Vec::new();
        if v.len() > self.min_len {
            // drop first half / second half / one element
            out.push(v[v.len() / 2..].to_vec());
            out.push(v[..v.len() / 2].to_vec());
            let mut one_less = v.clone();
            one_less.pop();
            out.push(one_less);
        }
        // shrink the largest element
        if let Some((i, _)) = v.iter().enumerate().max_by_key(|(_, &x)| x) {
            for smaller in self.elem.shrink(&v[i]) {
                let mut w = v.clone();
                w[i] = smaller;
                out.push(w);
            }
        }
        out.retain(|w| w.len() >= self.min_len);
        out
    }
}

/// Run a property: `prop` returns `Ok(())` or `Err(description)`.
/// Panics with the (shrunk) counterexample if the property fails.
pub fn check<T, G, P>(cfg: Config, gen: &G, prop: P)
where
    T: std::fmt::Debug + Clone,
    G: Gen<T>,
    P: Fn(&T) -> Result<(), String>,
{
    let mut rng = Xorshift::new(cfg.seed);
    for case in 0..cfg.cases {
        let value = gen.generate(&mut rng);
        if let Err(msg) = prop(&value) {
            // Shrink.
            let mut best = value.clone();
            let mut best_msg = msg;
            let mut steps = 0;
            'outer: loop {
                for cand in gen.shrink(&best) {
                    steps += 1;
                    if steps > cfg.max_shrink_steps {
                        break 'outer;
                    }
                    if let Err(m) = prop(&cand) {
                        best = cand;
                        best_msg = m;
                        continue 'outer;
                    }
                }
                break;
            }
            panic!(
                "property failed (case {case}, seed {seed:#x}):\n  input: {best:?}\n  error: {best_msg}",
                seed = cfg.seed
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(Config::default(), &UsizeIn { lo: 0, hi: 100 }, |&x| {
            if x <= 100 {
                Ok(())
            } else {
                Err("out of range".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        check(Config::default(), &UsizeIn { lo: 0, hi: 100 }, |&x| {
            if x < 50 {
                Ok(())
            } else {
                Err(format!("{x} >= 50"))
            }
        });
    }

    #[test]
    fn shrinks_to_minimal_counterexample() {
        let result = std::panic::catch_unwind(|| {
            check(Config { cases: 64, seed: 3, max_shrink_steps: 1024 }, &UsizeIn { lo: 0, hi: 1000 }, |&x| {
                if x < 500 {
                    Ok(())
                } else {
                    Err("too big".into())
                }
            });
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        // minimal failing input is 500
        assert!(msg.contains("input: 500"), "{msg}");
    }

    #[test]
    fn vec_gen_respects_bounds() {
        let gen = VecOfUsize { min_len: 1, max_len: 8, elem: UsizeIn { lo: 2, hi: 5 } };
        let mut rng = Xorshift::new(1);
        for _ in 0..200 {
            let v = gen.generate(&mut rng);
            assert!((1..=8).contains(&v.len()));
            assert!(v.iter().all(|&x| (2..=5).contains(&x)));
        }
    }
}
