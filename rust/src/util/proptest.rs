//! A miniature property-based testing framework (offline substitute for
//! `proptest`), used for coordinator and kernel invariants.
//!
//! Features: seeded case generation, failure shrinking for integer-vector
//! inputs, and readable counterexample reports via panic messages.

use crate::util::prng::Xorshift;

/// Configuration for a property run.
#[derive(Debug, Clone, Copy)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    pub max_shrink_steps: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 128, seed: 0xC0FFEE, max_shrink_steps: 512 }
    }
}

/// A generator of values of type `T` from a PRNG.
pub trait Gen<T> {
    fn generate(&self, rng: &mut Xorshift) -> T;
    /// Candidate "smaller" versions of a failing value (one shrink step).
    fn shrink(&self, value: &T) -> Vec<T> {
        let _ = value;
        Vec::new()
    }
}

/// Uniform usize in `[lo, hi]` inclusive; shrinks toward `lo`.
pub struct UsizeIn {
    pub lo: usize,
    pub hi: usize,
}

impl Gen<usize> for UsizeIn {
    fn generate(&self, rng: &mut Xorshift) -> usize {
        self.lo + rng.below(self.hi - self.lo + 1)
    }
    fn shrink(&self, v: &usize) -> Vec<usize> {
        let mut out = Vec::new();
        if *v > self.lo {
            out.push(self.lo);
            out.push(self.lo + (*v - self.lo) / 2);
            out.push(*v - 1);
        }
        out.dedup();
        out
    }
}

/// f64 in `[lo, hi)`; shrinks toward lo and 0 (if representable in range).
pub struct F64In {
    pub lo: f64,
    pub hi: f64,
}

impl Gen<f64> for F64In {
    fn generate(&self, rng: &mut Xorshift) -> f64 {
        self.lo + (self.hi - self.lo) * rng.next_f64()
    }
    fn shrink(&self, v: &f64) -> Vec<f64> {
        let mut out = vec![self.lo];
        if self.lo <= 0.0 && 0.0 < self.hi && *v != 0.0 {
            out.push(0.0);
        }
        out.push(self.lo + (*v - self.lo) / 2.0);
        out.retain(|x| x != v);
        out
    }
}

/// A randomized convolution geometry for kernel/scheduler property tests:
/// odd *and* even spatial sizes, strides 1–2, filter sizes 1/3/5, an extra
/// padding ring beyond "same", and a worker thread count — every knob the
/// row-sweep edge cases (truncated taps, skipped strided rows, boundary
/// columns) depend on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConvGeom {
    /// Input spatial size (H = W).
    pub hw: usize,
    /// Stride on both axes.
    pub stride: usize,
    /// Filter size (S = R): 1, 3 or 5.
    pub rs: usize,
    /// Padding rings added on top of the "same" padding `(rs-1)/2`.
    pub extra_pad: usize,
    /// Worker threads for scheduler properties.
    pub threads: usize,
}

/// Generator for [`ConvGeom`]: `hw` in `[min_hw, max_hw]`, `threads` in
/// `[1, max_threads]`, stride in `{1, 2}`, filter in `{1, 3, 5}`,
/// `extra_pad` in `{0, 1}`. Shrinks toward the smallest spatial size,
/// stride 1, filter 1×1, no extra padding and 1 thread.
pub struct ConvGeomGen {
    pub min_hw: usize,
    pub max_hw: usize,
    pub max_threads: usize,
}

impl Gen<ConvGeom> for ConvGeomGen {
    fn generate(&self, rng: &mut Xorshift) -> ConvGeom {
        ConvGeom {
            hw: self.min_hw + rng.below(self.max_hw - self.min_hw + 1),
            stride: 1 + rng.below(2),
            rs: [1, 3, 5][rng.below(3)],
            extra_pad: rng.below(2),
            threads: 1 + rng.below(self.max_threads),
        }
    }
    fn shrink(&self, v: &ConvGeom) -> Vec<ConvGeom> {
        let mut out = Vec::new();
        if v.hw > self.min_hw {
            out.push(ConvGeom { hw: self.min_hw, ..*v });
            out.push(ConvGeom { hw: v.hw - 1, ..*v });
        }
        if v.stride > 1 {
            out.push(ConvGeom { stride: 1, ..*v });
        }
        if v.rs > 1 {
            out.push(ConvGeom { rs: 1, ..*v });
            out.push(ConvGeom { rs: v.rs - 2, ..*v });
        }
        if v.extra_pad > 0 {
            out.push(ConvGeom { extra_pad: 0, ..*v });
        }
        if v.threads > 1 {
            out.push(ConvGeom { threads: 1, ..*v });
        }
        out
    }
}

/// Vector of usizes with length in `[min_len, max_len]`, elements from
/// `elem`. Shrinks by removing elements and shrinking single elements.
pub struct VecOfUsize {
    pub min_len: usize,
    pub max_len: usize,
    pub elem: UsizeIn,
}

impl Gen<Vec<usize>> for VecOfUsize {
    fn generate(&self, rng: &mut Xorshift) -> Vec<usize> {
        let len = self.min_len + rng.below(self.max_len - self.min_len + 1);
        (0..len).map(|_| self.elem.generate(rng)).collect()
    }
    fn shrink(&self, v: &Vec<usize>) -> Vec<Vec<usize>> {
        let mut out = Vec::new();
        if v.len() > self.min_len {
            // drop first half / second half / one element
            out.push(v[v.len() / 2..].to_vec());
            out.push(v[..v.len() / 2].to_vec());
            let mut one_less = v.clone();
            one_less.pop();
            out.push(one_less);
        }
        // shrink the largest element
        if let Some((i, _)) = v.iter().enumerate().max_by_key(|(_, &x)| x) {
            for smaller in self.elem.shrink(&v[i]) {
                let mut w = v.clone();
                w[i] = smaller;
                out.push(w);
            }
        }
        out.retain(|w| w.len() >= self.min_len);
        out
    }
}

/// Run a property: `prop` returns `Ok(())` or `Err(description)`.
/// Panics with the (shrunk) counterexample if the property fails.
pub fn check<T, G, P>(cfg: Config, gen: &G, prop: P)
where
    T: std::fmt::Debug + Clone,
    G: Gen<T>,
    P: Fn(&T) -> Result<(), String>,
{
    let mut rng = Xorshift::new(cfg.seed);
    for case in 0..cfg.cases {
        let value = gen.generate(&mut rng);
        if let Err(msg) = prop(&value) {
            // Shrink.
            let mut best = value.clone();
            let mut best_msg = msg;
            let mut steps = 0;
            'outer: loop {
                for cand in gen.shrink(&best) {
                    steps += 1;
                    if steps > cfg.max_shrink_steps {
                        break 'outer;
                    }
                    if let Err(m) = prop(&cand) {
                        best = cand;
                        best_msg = m;
                        continue 'outer;
                    }
                }
                break;
            }
            panic!(
                "property failed (case {case}, seed {seed:#x}):\n  input: {best:?}\n  error: {best_msg}",
                seed = cfg.seed
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(Config::default(), &UsizeIn { lo: 0, hi: 100 }, |&x| {
            if x <= 100 {
                Ok(())
            } else {
                Err("out of range".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        check(Config::default(), &UsizeIn { lo: 0, hi: 100 }, |&x| {
            if x < 50 {
                Ok(())
            } else {
                Err(format!("{x} >= 50"))
            }
        });
    }

    #[test]
    fn shrinks_to_minimal_counterexample() {
        let result = std::panic::catch_unwind(|| {
            check(Config { cases: 64, seed: 3, max_shrink_steps: 1024 }, &UsizeIn { lo: 0, hi: 1000 }, |&x| {
                if x < 500 {
                    Ok(())
                } else {
                    Err("too big".into())
                }
            });
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        // minimal failing input is 500
        assert!(msg.contains("input: 500"), "{msg}");
    }

    #[test]
    fn conv_geom_gen_respects_bounds_and_shrinks_down() {
        let gen = ConvGeomGen { min_hw: 4, max_hw: 11, max_threads: 8 };
        let mut rng = Xorshift::new(9);
        let mut seen_odd = false;
        let mut seen_even = false;
        for _ in 0..300 {
            let g = gen.generate(&mut rng);
            assert!((4..=11).contains(&g.hw));
            assert!((1..=2).contains(&g.stride));
            assert!([1, 3, 5].contains(&g.rs));
            assert!(g.extra_pad <= 1);
            assert!((1..=8).contains(&g.threads));
            seen_odd |= g.hw % 2 == 1;
            seen_even |= g.hw % 2 == 0;
        }
        assert!(seen_odd && seen_even, "must sweep odd and even spatial sizes");

        // every shrink candidate is strictly "smaller" in some axis and
        // stays in bounds
        let big = ConvGeom { hw: 11, stride: 2, rs: 5, extra_pad: 1, threads: 8 };
        for s in gen.shrink(&big) {
            assert!(s != big);
            assert!(s.hw >= gen.min_hw && [1, 3, 5].contains(&s.rs));
        }
        let minimal = ConvGeom { hw: 4, stride: 1, rs: 1, extra_pad: 0, threads: 1 };
        assert!(gen.shrink(&minimal).is_empty(), "minimal geometry must not shrink");
    }

    #[test]
    fn vec_gen_respects_bounds() {
        let gen = VecOfUsize { min_len: 1, max_len: 8, elem: UsizeIn { lo: 2, hi: 5 } };
        let mut rng = Xorshift::new(1);
        for _ in 0..200 {
            let v = gen.generate(&mut rng);
            assert!((1..=8).contains(&v.len()));
            assert!(v.iter().all(|&x| (2..=5).contains(&x)));
        }
    }
}
