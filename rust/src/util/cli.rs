//! Minimal command-line argument parsing (offline substitute for `clap`).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments and
//! subcommands. Unknown flags produce an error listing valid options.

use std::collections::BTreeMap;

/// Parsed arguments: options (`--k v`), flags (`--k`) and positionals.
#[derive(Debug, Default, Clone)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse from an explicit token list (testable) given the set of
    /// recognized value-taking options and boolean flags.
    pub fn parse_tokens(
        tokens: &[String],
        value_opts: &[&str],
        bool_flags: &[&str],
    ) -> Result<Args, String> {
        let mut out = Args::default();
        let mut i = 0;
        while i < tokens.len() {
            let t = &tokens[i];
            if let Some(stripped) = t.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                if bool_flags.contains(&key.as_str()) {
                    if inline_val.is_some() {
                        return Err(format!("flag --{key} does not take a value"));
                    }
                    out.flags.push(key);
                } else if value_opts.contains(&key.as_str()) {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            tokens
                                .get(i)
                                .cloned()
                                .ok_or_else(|| format!("--{key} requires a value"))?
                        }
                    };
                    out.opts.insert(key, val);
                } else {
                    return Err(format!(
                        "unknown option --{key}; valid options: {}, flags: {}",
                        value_opts.join(", "),
                        bool_flags.join(", ")
                    ));
                }
            } else {
                out.positional.push(t.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    /// Parse from `std::env::args()` (skipping argv[0]).
    pub fn from_env(value_opts: &[&str], bool_flags: &[&str]) -> Result<Args, String> {
        let tokens: Vec<String> = std::env::args().skip(1).collect();
        Args::parse_tokens(&tokens, value_opts, bool_flags)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(String::as_str)
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name} expects an integer, got '{v}'")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name} expects a number, got '{v}'")),
        }
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// First positional argument, used as the subcommand name.
    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(String::as_str)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &[&str]) -> Vec<String> {
        s.iter().map(|t| t.to_string()).collect()
    }

    #[test]
    fn parses_opts_flags_positionals() {
        let a = Args::parse_tokens(
            &toks(&["sweep", "--layer", "vgg3_2", "--host", "--iters=5"]),
            &["layer", "iters"],
            &["host"],
        )
        .unwrap();
        assert_eq!(a.subcommand(), Some("sweep"));
        assert_eq!(a.get("layer"), Some("vgg3_2"));
        assert!(a.flag("host"));
        assert_eq!(a.get_usize("iters", 1).unwrap(), 5);
    }

    #[test]
    fn unknown_option_errors() {
        let e = Args::parse_tokens(&toks(&["--nope"]), &["layer"], &["host"]).unwrap_err();
        assert!(e.contains("unknown option"));
    }

    #[test]
    fn missing_value_errors() {
        let e = Args::parse_tokens(&toks(&["--layer"]), &["layer"], &[]).unwrap_err();
        assert!(e.contains("requires a value"));
    }

    #[test]
    fn flag_with_value_errors() {
        let e = Args::parse_tokens(&toks(&["--host=1"]), &[], &["host"]).unwrap_err();
        assert!(e.contains("does not take a value"));
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse_tokens(&[], &["iters"], &[]).unwrap();
        assert_eq!(a.get_usize("iters", 7).unwrap(), 7);
        assert_eq!(a.get_or("iters", "x"), "x");
    }

    #[test]
    fn bad_int_errors() {
        let a = Args::parse_tokens(&toks(&["--iters", "abc"]), &["iters"], &[]).unwrap();
        assert!(a.get_usize("iters", 1).is_err());
    }
}
