//! Aligned text tables for experiment reports.
//!
//! Every bench target prints its figure/table with this, so `cargo bench`
//! output is directly comparable to the paper's tables.

/// A simple column-aligned text table builder.
#[derive(Debug, Default, Clone)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str) -> Table {
        Table { title: title.to_string(), ..Default::default() }
    }

    pub fn header<S: ToString>(mut self, cols: &[S]) -> Table {
        self.header = cols.iter().map(|c| c.to_string()).collect();
        self
    }

    pub fn row<S: ToString>(&mut self, cols: &[S]) -> &mut Table {
        self.rows.push(cols.iter().map(|c| c.to_string()).collect());
        self
    }

    /// Add a row from already-stringified cells.
    pub fn row_strings(&mut self, cols: Vec<String>) -> &mut Table {
        self.rows.push(cols);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn render(&self) -> String {
        let ncols = self
            .rows
            .iter()
            .map(|r| r.len())
            .chain(std::iter::once(self.header.len()))
            .max()
            .unwrap_or(0);
        let mut widths = vec![0usize; ncols];
        for (i, h) in self.header.iter().enumerate() {
            widths[i] = widths[i].max(h.len());
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::new();
            for (i, w) in widths.iter().enumerate() {
                let cell = cells.get(i).map(String::as_str).unwrap_or("");
                if i == 0 {
                    line.push_str(&format!("{cell:<w$}"));
                } else {
                    line.push_str(&format!("  {cell:>w$}"));
                }
            }
            line.push('\n');
            line
        };
        if !self.header.is_empty() {
            out.push_str(&fmt_row(&self.header));
            let total: usize = widths.iter().sum::<usize>() + 2 * (ncols.saturating_sub(1));
            out.push_str(&"-".repeat(total));
            out.push('\n');
        }
        for row in &self.rows {
            out.push_str(&fmt_row(row));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }

    /// Render as CSV (for plotting outside the harness).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        if !self.header.is_empty() {
            out.push_str(&self.header.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Format a ratio like the paper's tables (two decimals, e.g. "2.19").
pub fn fmt_ratio(x: f64) -> String {
    format!("{x:.2}")
}

/// Format a time in human-friendly units.
pub fn fmt_duration_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo").header(&["layer", "speedup"]);
        t.row(&["vgg1_2", "1.04"]);
        t.row(&["resnet5_2", "2.48"]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("vgg1_2"));
        // all data lines have the same length
        let lines: Vec<&str> = s.lines().skip(1).collect();
        assert_eq!(lines[1].len(), lines[2].len().max(lines[3].len()));
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new("x").header(&["a", "b"]);
        t.row(&["v,1", "2"]);
        let csv = t.to_csv();
        assert!(csv.contains("\"v,1\",2"));
    }

    #[test]
    fn duration_units() {
        assert_eq!(fmt_duration_ns(500.0), "500.0 ns");
        assert_eq!(fmt_duration_ns(1500.0), "1.50 µs");
        assert_eq!(fmt_duration_ns(2.5e6), "2.50 ms");
        assert_eq!(fmt_duration_ns(3.0e9), "3.000 s");
    }
}
