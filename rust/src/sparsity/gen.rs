//! Synthetic sparse-pattern generators (§4: "we generate synthetic input
//! with random sparse patterns").

use crate::tensor::ActTensor;
use crate::util::prng::Xorshift;

/// Zero-pattern families for robustness experiments. The paper evaluates
/// i.i.d. random patterns; channel- and row-structured variants probe the
/// zero-check's sensitivity to clustering (the vector mask benefits from
/// whole-vector zeros).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Pattern {
    /// i.i.d. Bernoulli zeros (the paper's synthetic inputs).
    Iid,
    /// Whole channels zero with probability `s` (pruning-like structure).
    ChannelStructured,
    /// Contiguous zero runs along rows (spatially-correlated ReLU maps).
    RowRuns {
        mean_run: usize,
    },
}

/// Fill `t` as a ReLU output with target `sparsity` under the pattern.
pub fn fill_pattern(t: &mut ActTensor, rng: &mut Xorshift, sparsity: f64, pattern: Pattern) {
    match pattern {
        Pattern::Iid => t.fill_relu_sparse(rng, sparsity),
        Pattern::ChannelStructured => {
            for i in 0..t.n {
                for c in 0..t.c {
                    let zero = rng.bernoulli(sparsity);
                    for y in 0..t.h {
                        for x in 0..t.w {
                            let v = if zero { 0.0 } else { 0.05 + rng.next_f32() };
                            t.set(i, c, y, x, v);
                        }
                    }
                }
            }
        }
        Pattern::RowRuns { mean_run } => {
            let mean_run = mean_run.max(1);
            for i in 0..t.n {
                for c in 0..t.c {
                    for y in 0..t.h {
                        let mut x = 0;
                        while x < t.w {
                            let zero = rng.bernoulli(sparsity);
                            // geometric-ish run length around mean_run
                            let mut run = 1 + rng.below(2 * mean_run);
                            while run > 0 && x < t.w {
                                let v = if zero { 0.0 } else { 0.05 + rng.next_f32() };
                                t.set(i, c, y, x, v);
                                x += 1;
                                run -= 1;
                            }
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn iid_hits_target() {
        let mut rng = Xorshift::new(1);
        let mut t = ActTensor::zeros(2, 64, 16, 16);
        fill_pattern(&mut t, &mut rng, 0.65, Pattern::Iid);
        assert!((t.sparsity() - 0.65).abs() < 0.02);
    }

    #[test]
    fn channel_structured_zeros_whole_channels() {
        let mut rng = Xorshift::new(2);
        let mut t = ActTensor::zeros(2, 64, 8, 8);
        fill_pattern(&mut t, &mut rng, 0.5, Pattern::ChannelStructured);
        // each (i, c) plane is all-zero or all-nonzero
        for i in 0..2 {
            for c in 0..64 {
                let mut zeros = 0;
                for y in 0..8 {
                    for x in 0..8 {
                        if t.get(i, c, y, x) == 0.0 {
                            zeros += 1;
                        }
                    }
                }
                assert!(zeros == 0 || zeros == 64, "plane ({i},{c}) mixed: {zeros}");
            }
        }
        assert!((t.sparsity() - 0.5).abs() < 0.15);
    }

    #[test]
    fn row_runs_roughly_hits_target() {
        let mut rng = Xorshift::new(3);
        let mut t = ActTensor::zeros(2, 32, 16, 16);
        fill_pattern(&mut t, &mut rng, 0.7, Pattern::RowRuns { mean_run: 4 });
        assert!((t.sparsity() - 0.7).abs() < 0.08, "s={}", t.sparsity());
    }
}
