//! Dynamic-sparsity substrate: synthetic pattern generators, the training
//! sparsity-trajectory model behind Figure 3, and an activation profiler.

pub mod gen;
pub mod profiler;
pub mod trace;

pub use gen::{fill_pattern, Pattern};
pub use profiler::SparsityProfiler;
pub use trace::{TrajectoryModel, TrajectoryParams};
