//! Activation-sparsity profiler: records per-layer ReLU-output sparsity
//! during real training runs (used by the trainer and the end-to-end
//! example to produce measured Fig-3-style traces).

use crate::tensor::ActTensor;
use std::collections::BTreeMap;

/// Accumulates sparsity observations keyed by layer name.
#[derive(Debug, Default, Clone)]
pub struct SparsityProfiler {
    /// layer → (per-step sparsity observations)
    samples: BTreeMap<String, Vec<f64>>,
}

impl SparsityProfiler {
    pub fn new() -> SparsityProfiler {
        SparsityProfiler::default()
    }

    /// Record the sparsity of an activation tensor.
    pub fn observe(&mut self, layer: &str, t: &ActTensor) {
        self.observe_value(layer, t.sparsity());
    }

    /// Record a pre-computed sparsity value (e.g. from PJRT outputs).
    pub fn observe_value(&mut self, layer: &str, sparsity: f64) {
        assert!((0.0..=1.0).contains(&sparsity), "sparsity {sparsity} out of range");
        self.samples.entry(layer.to_string()).or_default().push(sparsity);
    }

    pub fn layers(&self) -> Vec<&str> {
        self.samples.keys().map(String::as_str).collect()
    }

    /// All observations for a layer, in arrival order.
    pub fn series(&self, layer: &str) -> Option<&[f64]> {
        self.samples.get(layer).map(Vec::as_slice)
    }

    /// Mean sparsity for a layer.
    pub fn mean(&self, layer: &str) -> Option<f64> {
        self.series(layer).map(crate::util::stats::mean)
    }

    /// Mean sparsity over the most recent `window` observations — the
    /// signal the dynamic algorithm selector uses (§5.3's "profile the
    /// sparsity of each layer at intervals" suggestion).
    pub fn recent_mean(&self, layer: &str, window: usize) -> Option<f64> {
        self.series(layer).map(|s| {
            let tail = &s[s.len().saturating_sub(window)..];
            crate::util::stats::mean(tail)
        })
    }

    /// Render a compact report table.
    pub fn report(&self) -> crate::util::table::Table {
        let mut t = crate::util::table::Table::new("ReLU output sparsity (measured)")
            .header(&["layer", "mean", "first", "last", "n"]);
        for (layer, s) in &self.samples {
            t.row_strings(vec![
                layer.clone(),
                format!("{:.3}", crate::util::stats::mean(s)),
                format!("{:.3}", s.first().copied().unwrap_or(0.0)),
                format!("{:.3}", s.last().copied().unwrap_or(0.0)),
                s.len().to_string(),
            ]);
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Xorshift;

    #[test]
    fn observes_and_aggregates() {
        let mut p = SparsityProfiler::new();
        let mut rng = Xorshift::new(4);
        let mut t = ActTensor::zeros(1, 16, 8, 8);
        t.fill_relu_sparse(&mut rng, 0.6);
        p.observe("conv1", &t);
        t.fill_relu_sparse(&mut rng, 0.8);
        p.observe("conv1", &t);
        let m = p.mean("conv1").unwrap();
        assert!((m - 0.7).abs() < 0.05, "mean={m}");
        assert_eq!(p.series("conv1").unwrap().len(), 2);
    }

    #[test]
    fn recent_mean_windows() {
        let mut p = SparsityProfiler::new();
        for s in [0.1, 0.2, 0.8, 0.9] {
            p.observe_value("l", s);
        }
        assert!((p.recent_mean("l", 2).unwrap() - 0.85).abs() < 1e-12);
        assert!((p.recent_mean("l", 100).unwrap() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn unknown_layer_is_none() {
        let p = SparsityProfiler::new();
        assert!(p.mean("nope").is_none());
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn rejects_invalid_sparsity() {
        let mut p = SparsityProfiler::new();
        p.observe_value("l", 1.5);
    }
}
