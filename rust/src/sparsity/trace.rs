//! Sparsity-trajectory model for Figure 3.
//!
//! The paper profiles ReLU-output sparsity over 100-epoch ImageNet training
//! of ResNet-34/50/Fixup-50 and reports (§5.3, after Rhu et al. [30]):
//! * sparsity starts near 50 % (weights centered at 0);
//! * rises rapidly in the first several epochs, then slowly decreases;
//! * later layers are sparser than earlier layers;
//! * residual shortcuts add positive bias to block outputs → the ReLU after
//!   each block is *less* sparse, producing a periodic fluctuation across
//!   adjacent layers — more pronounced in ResNet-34 and Fixup ResNet-50
//!   than in ResNet-50.
//!
//! We have no 100-epoch ImageNet budget, so this parametric model generates
//! the trajectories; its *shape* is validated against a real (small-scale)
//! training run by `examples/end_to_end_train.rs`, which logs measured
//! per-layer sparsity from the PJRT-executed trainer.

/// Parameters of the trajectory model for one network.
#[derive(Debug, Clone, Copy)]
pub struct TrajectoryParams {
    /// Initial sparsity at epoch 0 (≈ 0.5 by the ReLU argument).
    pub s0: f64,
    /// Peak sparsity gain at the deepest layer.
    pub depth_gain: f64,
    /// Epochs to reach the early peak.
    pub ramp_epochs: f64,
    /// Slow late-training decay per epoch.
    pub decay_per_epoch: f64,
    /// Magnitude of the residual-shortcut dip on post-block ReLUs.
    pub shortcut_dip: f64,
    /// Layers per residual block (dip period); 0 disables fluctuation.
    pub block_period: usize,
}

impl TrajectoryParams {
    pub fn vgg16() -> TrajectoryParams {
        TrajectoryParams {
            s0: 0.5,
            depth_gain: 0.42,
            ramp_epochs: 8.0,
            decay_per_epoch: 0.0008,
            shortcut_dip: 0.0,
            block_period: 0,
        }
    }

    pub fn resnet34() -> TrajectoryParams {
        TrajectoryParams {
            s0: 0.5,
            depth_gain: 0.38,
            ramp_epochs: 10.0,
            decay_per_epoch: 0.0009,
            shortcut_dip: 0.18,
            block_period: 2,
        }
    }

    pub fn resnet50() -> TrajectoryParams {
        TrajectoryParams {
            s0: 0.5,
            depth_gain: 0.30,
            ramp_epochs: 10.0,
            decay_per_epoch: 0.0010,
            shortcut_dip: 0.08,
            block_period: 3,
        }
    }

    pub fn fixup_resnet50() -> TrajectoryParams {
        TrajectoryParams {
            s0: 0.5,
            depth_gain: 0.34,
            ramp_epochs: 9.0,
            decay_per_epoch: 0.0009,
            shortcut_dip: 0.16,
            block_period: 3,
        }
    }
}

/// Generates per-layer, per-epoch ReLU-output sparsity.
#[derive(Debug, Clone)]
pub struct TrajectoryModel {
    pub params: TrajectoryParams,
    pub layers: usize,
    pub epochs: usize,
}

impl TrajectoryModel {
    pub fn new(params: TrajectoryParams, layers: usize, epochs: usize) -> TrajectoryModel {
        TrajectoryModel { params, layers, epochs }
    }

    /// Sparsity of `layer` (0-based, input side → output side) at `epoch`.
    pub fn sparsity(&self, layer: usize, epoch: usize) -> f64 {
        let p = &self.params;
        let depth = if self.layers > 1 {
            layer as f64 / (self.layers - 1) as f64
        } else {
            1.0
        };
        // depth profile: later layers sparser (concave ramp)
        let depth_target = p.s0 + p.depth_gain * depth.powf(0.7);
        // time profile: fast ramp to the target, then slow decay
        let e = epoch as f64;
        let ramp = 1.0 - (-e / p.ramp_epochs).exp();
        let decay = 1.0 - p.decay_per_epoch * (e - p.ramp_epochs).max(0.0);
        let mut s = p.s0 + (depth_target - p.s0) * ramp;
        s *= decay;
        // residual fluctuation: the ReLU right after a shortcut-add is less
        // sparse (positive bias from the skip path)
        if p.block_period > 0 && (layer + 1) % p.block_period == 0 {
            s -= p.shortcut_dip * ramp;
        }
        s.clamp(0.05, 0.97)
    }

    /// Mean sparsity of a layer across all epochs (drives the Fig-4/Table-6
    /// static projections).
    pub fn mean_sparsity(&self, layer: usize) -> f64 {
        (0..self.epochs).map(|e| self.sparsity(layer, e)).sum::<f64>() / self.epochs as f64
    }

    /// The full trajectory matrix `[layer][epoch]`.
    pub fn matrix(&self) -> Vec<Vec<f64>> {
        (0..self.layers)
            .map(|l| (0..self.epochs).map(|e| self.sparsity(l, e)).collect())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> TrajectoryModel {
        TrajectoryModel::new(TrajectoryParams::resnet34(), 32, 100)
    }

    #[test]
    fn starts_near_half() {
        let m = model();
        for l in 0..m.layers {
            let s = m.sparsity(l, 0);
            assert!((0.25..0.6).contains(&s), "layer {l} epoch0 s={s}");
        }
    }

    #[test]
    fn ramps_up_then_slowly_decays() {
        let m = model();
        let l = 20;
        let early = m.sparsity(l, 0);
        let peak = m.sparsity(l, 30);
        let late = m.sparsity(l, 99);
        assert!(peak > early + 0.1, "no ramp: {early} → {peak}");
        assert!(late < peak, "no late decay: {peak} → {late}");
        assert!(late > peak - 0.15, "decay too fast");
    }

    #[test]
    fn later_layers_sparser() {
        let m = TrajectoryModel::new(TrajectoryParams::vgg16(), 12, 100);
        let early_layer = m.mean_sparsity(1);
        let late_layer = m.mean_sparsity(10);
        assert!(late_layer > early_layer + 0.1);
    }

    #[test]
    fn vgg_reaches_80_plus_on_late_layers() {
        // Rhu et al.: most VGG16 layers over 80 % sparse on average.
        let m = TrajectoryModel::new(TrajectoryParams::vgg16(), 12, 100);
        assert!(m.mean_sparsity(11) > 0.8, "{}", m.mean_sparsity(11));
    }

    #[test]
    fn residual_fluctuation_present_and_stronger_in_resnet34() {
        let m34 = TrajectoryModel::new(TrajectoryParams::resnet34(), 32, 100);
        let m50 = TrajectoryModel::new(TrajectoryParams::resnet50(), 48, 100);
        // dip at block boundary vs neighbor
        let dip34 = m34.mean_sparsity(14) - m34.mean_sparsity(15); // 16th layer ends block
        let dip50 = m50.mean_sparsity(13) - m50.mean_sparsity(14);
        assert!(dip34 > 0.05, "resnet34 dip missing: {dip34}");
        assert!(dip34 > dip50, "fluctuation should be stronger in resnet34");
    }

    #[test]
    fn bounded_in_unit_interval() {
        let m = model();
        for l in 0..m.layers {
            for e in 0..m.epochs {
                let s = m.sparsity(l, e);
                assert!((0.0..=1.0).contains(&s));
            }
        }
    }
}
