//! SparseTrain CLI — the L3 coordinator entrypoint.
//!
//! ```text
//! sparsetrain table3|table4|table5|table6|fig1|fig2|fig3|fig4   experiments
//! sparsetrain sweep --layer vgg3_2                              one layer
//! sparsetrain train --steps 200                                 PJRT trainer
//! sparsetrain serve --smoke                                     batch server
//! sparsetrain plan --k 256 --r 3                                register plan
//! ```

use sparsetrain::bench::experiments;
use sparsetrain::bench::loadgen::{
    self, run_serve_bench, scenario_by_name, smoke_violations, wallclock_report, ArrivalKind,
    ServeBenchConfig,
};
use sparsetrain::coordinator::serve::ServeConfig;
use sparsetrain::coordinator::trainer::{Trainer, TrainerConfig};
use sparsetrain::kernels::regalloc::{plan_bww, plan_fwd};
use sparsetrain::kernels::Component;
use sparsetrain::nets::table2::layer_by_name;
use sparsetrain::nets::{Network, Scale};
use sparsetrain::runtime::artifacts::ArtifactSet;
use sparsetrain::sim::{Algorithm, Machine};
use sparsetrain::util::cli::Args;

const USAGE: &str = "\
sparsetrain — SparseTrain reproduction (dynamic ReLU sparsity on SIMD CPUs)

USAGE: sparsetrain <command> [options]

COMMANDS
  fig1 | table4      3x3 layers: speedup vs sparsity (model)
  fig2 | table5      1x1 layers: speedup vs sparsity (model)
  fig3               sparsity trajectories over training
  fig4 | table6      end-to-end projections  [--epochs N]
  table3             register-budget plans (Q/T/pipelining)
  sweep              one layer  [--layer NAME] [--csv]
  train              run the PJRT trainer  [--steps N] [--seed N]
                     [--net vgg16|resnet34|resnet50|fixup_resnet50]
                     [--scale small|medium|full]
                     (--net emits and trains the full multi-layer zoo
                      inventory — residual blocks, strided downsamples,
                      BN-position-aware ReLUs — instead of the classic
                      two-conv paper geometry; --scale shrinks spatial
                      extent and stage depth so deep nets run quickly,
                      default small. --threads N sizes the op router's
                      kernel/GEMM executor; default 0 = host parallelism.
                      Prints per-op-kind and, with --net, per-layer
                      routed/fallback counters;
                      SPARSETRAIN_CONV_ROUTE=off / SPARSETRAIN_OP_ROUTE=off
                      disable routing classes. The measured-cost DB
                      (COSTDB_kernels.json) drives skip-mode selection;
                      SPARSETRAIN_COST_DB=off reverts to the analytic
                      model, =fresh resets, SPARSETRAIN_COST_DB_PATH
                      relocates the store. At >= 2 threads the dependency-
                      scheduled evaluator overlaps independent backward ops
                      when measured costs say a lone op under-fills the
                      pool; prints the pipeline state, overlap-pair count
                      and pool-utilization EMA. SPARSETRAIN_PIPELINE=off
                      restores strictly sequential evaluation.)
  serve              batched sparse-inference server under synthetic load
                     [--smoke] [--rate RPS] [--requests N] [--max-batch N]
                     [--deadline-us N] [--depth N] [--threads N] [--seed N]
                     [--scenario paper|hires32|wide64|all] [--out FILE]
                     (Open-loop seeded Poisson arrivals drive the batching
                      front end over the routed predict ladder; prints
                      p50/p95/p99 latency, throughput and the batch-size
                      histogram per scenario and writes them as
                      component:\"serve\" rows in the wallclock v5 schema,
                      default BENCH_serve.json. Batch-size selection uses
                      the measured-cost DB when warm, static max-batch
                      otherwise — SPARSETRAIN_COST_DB=off pins static.
                      --smoke runs one short low-rate scenario and exits
                      nonzero on any reject / zero throughput / non-finite
                      p99.)
  plan               register plan  [--k N] [--r N]

OPTIONS
  --threads N        model N active cores (default: the testbed's 6)

All experiment outputs are also produced by `cargo bench` and the examples.";

/// Parse-or-die for numeric options: every malformed value is a usage
/// error (exit 2), matching the analytics path — no silent fallback to
/// the default.
fn usize_opt(args: &Args, name: &str, default: usize) -> usize {
    args.get_usize(name, default).unwrap_or_else(|e| {
        eprintln!("error: {e}\n\n{USAGE}");
        std::process::exit(2);
    })
}

fn main() {
    let args = Args::from_env(
        &[
            "layer",
            "steps",
            "seed",
            "epochs",
            "k",
            "r",
            "threads",
            "net",
            "scale",
            "rate",
            "requests",
            "max-batch",
            "deadline-us",
            "depth",
            "scenario",
            "out",
        ],
        &["csv", "detail", "smoke"],
    )
    .unwrap_or_else(|e| {
        eprintln!("error: {e}\n\n{USAGE}");
        std::process::exit(2);
    });
    let base = Machine::skylake_x();
    let threads = usize_opt(&args, "threads", base.cores);
    let m = experiments::machine_with_threads(&base, threads);
    match args.subcommand() {
        Some("fig1") | Some("table4") => {
            let (_, fig, tab) = experiments::fig1_table4(&m);
            fig.print();
            tab.print();
        }
        Some("fig2") | Some("table5") => {
            let (_, fig, tab) = experiments::fig2_table5(&m);
            fig.print();
            tab.print();
        }
        Some("fig3") => {
            for (net, matrix) in experiments::fig3(100) {
                println!(
                    "{}: {} layers; layer-0 mean {:.2}, last-layer mean {:.2}",
                    net.name(),
                    matrix.len(),
                    sparsetrain::util::stats::mean(&matrix[0]),
                    sparsetrain::util::stats::mean(matrix.last().unwrap())
                );
            }
        }
        Some("fig4") | Some("table6") => {
            let epochs = usize_opt(&args, "epochs", 100);
            let (_, fig, tab) = experiments::fig4_table6(&m, epochs);
            fig.print();
            tab.print();
        }
        Some("table3") => {
            for r in [1usize, 3, 5] {
                let p = plan_fwd(256, r);
                println!(
                    "R={r}: Q={} T={} pipelined={} registers={}",
                    p.q, p.t, p.pipelined, p.registers
                );
            }
        }
        Some("plan") => {
            let k = usize_opt(&args, "k", 256);
            let r = usize_opt(&args, "r", 3);
            let f = plan_fwd(k, r);
            let b = plan_bww(k, r);
            println!("FWD/BWI: {f:?}");
            println!("BWW    : {b:?}");
        }
        Some("sweep") => {
            let layer = args.get_or("layer", "vgg3_2");
            let Some(nl) = layer_by_name(layer) else {
                eprintln!("unknown layer '{layer}'");
                std::process::exit(2);
            };
            for comp in Component::ALL {
                print!("{}: ", comp.name());
                for &s in &experiments::SPARSITY_GRID {
                    print!(
                        "{:.2} ",
                        experiments::speedup_over_direct(
                            &m,
                            Algorithm::SparseTrain,
                            &nl.cfg,
                            comp,
                            s
                        )
                    );
                }
                println!();
            }
        }
        Some("train") => {
            let steps = usize_opt(&args, "steps", 200);
            let seed = usize_opt(&args, "seed", 7) as u64;
            // For the trainer, --threads sizes the op router's kernel/GEMM
            // executor (default 0 = host parallelism), not the cost model.
            let trainer_threads = usize_opt(&args, "threads", 0);
            let net = args.get("net").map(|v| {
                Network::parse(v).unwrap_or_else(|| {
                    eprintln!("error: unknown --net '{v}'\n\n{USAGE}");
                    std::process::exit(2);
                })
            });
            let scale = match args.get("scale") {
                Some(v) => Scale::parse(v).unwrap_or_else(|| {
                    eprintln!("error: unknown --scale '{v}'\n\n{USAGE}");
                    std::process::exit(2);
                }),
                None => Scale::Small,
            };
            if net.is_none() && args.get("scale").is_some() {
                eprintln!("error: --scale requires --net\n\n{USAGE}");
                std::process::exit(2);
            }
            // Use real artifacts when present; otherwise materialize the
            // Rust-emitted reference HLO so training works offline.
            let artifacts = match ArtifactSet::bootstrap_offline() {
                Ok(set) => set,
                Err(e) => {
                    eprintln!("materializing offline artifacts failed: {e}");
                    std::process::exit(1);
                }
            };
            let cfg = TrainerConfig { steps, seed, log_every: 20, threads: trainer_threads, pipeline: None };
            let built = match net {
                Some(network) => Trainer::new_net(&artifacts, network, scale, cfg),
                None => Trainer::new(&artifacts, cfg),
            };
            match built {
                Ok(mut t) => match t.run() {
                    Ok(report) => {
                        report.profiler.report().print();
                        if let Some(router) = t.op_router() {
                            let s = router.stats();
                            println!(
                                "op-router: conv {}/{} routed, dot {}/{} routed, \
                                 {} chains fused, elementwise {}/{} routed \
                                 (routed/attempted; {} threads)",
                                s.conv_routed,
                                s.conv_routed + s.conv_fallback,
                                s.dot_routed,
                                s.dot_routed + s.dot_fallback,
                                s.fused,
                                s.ew_routed,
                                s.ew_routed + s.ew_fallback,
                                router.threads()
                            );
                            match router.cost_db() {
                                Some(db) => {
                                    let (hits, misses, updates) = db.counters();
                                    println!(
                                        "costdb: {hits} hits, {misses} misses, \
                                         {updates} updates ({} entries{})",
                                        db.len(),
                                        db.path()
                                            .map(|p| format!("; {}", p.display()))
                                            .unwrap_or_default()
                                    );
                                }
                                None => println!("costdb: off (analytic selector)"),
                            }
                            let per_layer = router.conv_layer_stats();
                            if !per_layer.is_empty() {
                                println!("per-conv routing (instr: routed/fallback):");
                                for (nm, routed, fb) in per_layer {
                                    let flag = if fb > 0 { "  <- fallback!" } else { "" };
                                    println!("  {nm}: {routed}/{fb}{flag}");
                                }
                            }
                            // Overlap + utilization make a pipeline that
                            // never fires visible in plain CLI output.
                            println!(
                                "pipeline: {} ({} overlap pairs)",
                                if t.pipelined() { "on" } else { "off" },
                                router.overlap_pairs()
                            );
                            match router.pool_utilization() {
                                Some(u) => println!(
                                    "pool-utilization: {:.1}% (busy-worker EMA)",
                                    u * 100.0
                                ),
                                None => println!("pool-utilization: n/a (no timed sweeps)"),
                            }
                        } else {
                            println!("op-router: disabled (naive interpreter)");
                            println!("pipeline: off (no op router)");
                        }
                        println!(
                            "done: {} steps, {:.1} steps/s, learned={}",
                            report.losses.len(),
                            report.steps_per_sec,
                            report.learned()
                        );
                    }
                    Err(e) => {
                        eprintln!("training failed: {e:#}");
                        std::process::exit(1);
                    }
                },
                Err(e) => {
                    eprintln!("{e:#}");
                    std::process::exit(1);
                }
            }
        }
        Some("serve") => {
            let smoke = args.flag("smoke");
            let rate = args.get_f64("rate", if smoke { 100.0 } else { 400.0 }).unwrap_or_else(|e| {
                eprintln!("error: {e}\n\n{USAGE}");
                std::process::exit(2);
            });
            let requests = usize_opt(&args, "requests", if smoke { 50 } else { 400 });
            let max_batch = usize_opt(&args, "max-batch", 8);
            let deadline_us = usize_opt(&args, "deadline-us", 2000);
            // Smoke structurally guarantees zero rejects regardless of
            // machine speed: the queue is deeper than the request count.
            let depth = usize_opt(&args, "depth", if smoke { 256 } else { 64 });
            let serve_threads = usize_opt(&args, "threads", 2);
            let seed = usize_opt(&args, "seed", 42) as u64;
            let scenario = args.get_or("scenario", if smoke { "paper" } else { "all" });
            let out = args.get_or("out", "BENCH_serve.json");
            if !(rate > 0.0 && rate.is_finite()) || requests == 0 || max_batch == 0 || depth == 0 {
                eprintln!(
                    "error: --rate must be positive and --requests/--max-batch/--depth \
                     at least 1\n\n{USAGE}"
                );
                std::process::exit(2);
            }
            let scs = if scenario == "all" {
                loadgen::scenarios()
            } else {
                match scenario_by_name(scenario) {
                    Some(sc) => vec![sc],
                    None => {
                        eprintln!("error: unknown --scenario '{scenario}'\n\n{USAGE}");
                        std::process::exit(2);
                    }
                }
            };
            let cfg = ServeBenchConfig {
                rate_rps: rate,
                requests,
                seed,
                serve: ServeConfig {
                    max_batch,
                    max_delay_ns: deadline_us as u64 * 1_000,
                    queue_depth: depth,
                },
                threads: serve_threads,
                arrivals: ArrivalKind::Poisson,
            };
            let reports = match run_serve_bench(&scs, &cfg) {
                Ok(r) => r,
                Err(e) => {
                    eprintln!("serve bench failed: {e:#}");
                    std::process::exit(1);
                }
            };
            let report = wallclock_report(&reports);
            if let Err(e) = report.write_json(std::path::Path::new(out)) {
                eprintln!("writing {out} failed: {e}");
                std::process::exit(1);
            }
            println!("wrote {} serve rows ({}) to {out}", reports.len(), loadgen::schema());
            if smoke {
                let violations = smoke_violations(&reports);
                if !violations.is_empty() {
                    for v in &violations {
                        eprintln!("serve smoke violation: {v}");
                    }
                    std::process::exit(1);
                }
                println!("serve smoke OK");
            }
        }
        _ => {
            println!("{USAGE}");
        }
    }
}
