//! SparseTrain CLI — the L3 coordinator entrypoint.
//!
//! ```text
//! sparsetrain table3|table4|table5|table6|fig1|fig2|fig3|fig4   experiments
//! sparsetrain sweep --layer vgg3_2                              one layer
//! sparsetrain train --steps 200                                 PJRT trainer
//! sparsetrain plan --k 256 --r 3                                register plan
//! ```

use sparsetrain::bench::experiments;
use sparsetrain::coordinator::trainer::{Trainer, TrainerConfig};
use sparsetrain::kernels::regalloc::{plan_bww, plan_fwd};
use sparsetrain::kernels::Component;
use sparsetrain::nets::table2::layer_by_name;
use sparsetrain::runtime::artifacts::ArtifactSet;
use sparsetrain::sim::{Algorithm, Machine};
use sparsetrain::util::cli::Args;

const USAGE: &str = "\
sparsetrain — SparseTrain reproduction (dynamic ReLU sparsity on SIMD CPUs)

USAGE: sparsetrain <command> [options]

COMMANDS
  fig1 | table4      3x3 layers: speedup vs sparsity (model)
  fig2 | table5      1x1 layers: speedup vs sparsity (model)
  fig3               sparsity trajectories over training
  fig4 | table6      end-to-end projections  [--epochs N]
  table3             register-budget plans (Q/T/pipelining)
  sweep              one layer  [--layer NAME] [--csv]
  train              run the PJRT trainer  [--steps N] [--seed N]
                     (--threads N sizes the op router's kernel/GEMM
                      executor; default 0 = host parallelism. Prints
                      per-op-kind routed/fallback/fused counters;
                      SPARSETRAIN_CONV_ROUTE=off / SPARSETRAIN_OP_ROUTE=off
                      disable routing classes.)
  plan               register plan  [--k N] [--r N]

OPTIONS
  --threads N        model N active cores (default: the testbed's 6)

All experiment outputs are also produced by `cargo bench` and the examples.";

fn main() {
    let args = Args::from_env(
        &["layer", "steps", "seed", "epochs", "k", "r", "threads"],
        &["csv", "detail"],
    )
    .unwrap_or_else(|e| {
        eprintln!("error: {e}\n\n{USAGE}");
        std::process::exit(2);
    });
    let base = Machine::skylake_x();
    let threads = args.get_usize("threads", base.cores).unwrap_or_else(|e| {
        eprintln!("error: {e}\n\n{USAGE}");
        std::process::exit(2);
    });
    let m = experiments::machine_with_threads(&base, threads);
    match args.subcommand() {
        Some("fig1") | Some("table4") => {
            let (_, fig, tab) = experiments::fig1_table4(&m);
            fig.print();
            tab.print();
        }
        Some("fig2") | Some("table5") => {
            let (_, fig, tab) = experiments::fig2_table5(&m);
            fig.print();
            tab.print();
        }
        Some("fig3") => {
            for (net, matrix) in experiments::fig3(100) {
                println!(
                    "{}: {} layers; layer-0 mean {:.2}, last-layer mean {:.2}",
                    net.name(),
                    matrix.len(),
                    sparsetrain::util::stats::mean(&matrix[0]),
                    sparsetrain::util::stats::mean(matrix.last().unwrap())
                );
            }
        }
        Some("fig4") | Some("table6") => {
            let epochs = args.get_usize("epochs", 100).unwrap_or(100);
            let (_, fig, tab) = experiments::fig4_table6(&m, epochs);
            fig.print();
            tab.print();
        }
        Some("table3") => {
            for r in [1usize, 3, 5] {
                let p = plan_fwd(256, r);
                println!(
                    "R={r}: Q={} T={} pipelined={} registers={}",
                    p.q, p.t, p.pipelined, p.registers
                );
            }
        }
        Some("plan") => {
            let k = args.get_usize("k", 256).unwrap_or(256);
            let r = args.get_usize("r", 3).unwrap_or(3);
            let f = plan_fwd(k, r);
            let b = plan_bww(k, r);
            println!("FWD/BWI: {f:?}");
            println!("BWW    : {b:?}");
        }
        Some("sweep") => {
            let layer = args.get_or("layer", "vgg3_2");
            let Some(nl) = layer_by_name(layer) else {
                eprintln!("unknown layer '{layer}'");
                std::process::exit(2);
            };
            for comp in Component::ALL {
                print!("{}: ", comp.name());
                for &s in &experiments::SPARSITY_GRID {
                    print!(
                        "{:.2} ",
                        experiments::speedup_over_direct(
                            &m,
                            Algorithm::SparseTrain,
                            &nl.cfg,
                            comp,
                            s
                        )
                    );
                }
                println!();
            }
        }
        Some("train") => {
            let steps = args.get_usize("steps", 200).unwrap_or(200);
            let seed = args.get_usize("seed", 7).unwrap_or(7) as u64;
            // For the trainer, --threads sizes the op router's kernel/GEMM
            // executor (default 0 = host parallelism), not the cost model.
            let trainer_threads = args.get_usize("threads", 0).unwrap_or(0);
            // Use real artifacts when present; otherwise materialize the
            // Rust-emitted reference HLO so training works offline.
            let artifacts = match ArtifactSet::bootstrap_offline() {
                Ok(set) => set,
                Err(e) => {
                    eprintln!("materializing offline artifacts failed: {e}");
                    std::process::exit(1);
                }
            };
            match Trainer::new(
                &artifacts,
                TrainerConfig { steps, seed, log_every: 20, threads: trainer_threads },
            ) {
                Ok(mut t) => match t.run() {
                    Ok(report) => {
                        report.profiler.report().print();
                        if let Some(router) = t.op_router() {
                            let s = router.stats();
                            println!(
                                "op-router: conv {}/{} routed, dot {}/{} routed, \
                                 {} chains fused, elementwise {}/{} routed \
                                 (routed/attempted; {} threads)",
                                s.conv_routed,
                                s.conv_routed + s.conv_fallback,
                                s.dot_routed,
                                s.dot_routed + s.dot_fallback,
                                s.fused,
                                s.ew_routed,
                                s.ew_routed + s.ew_fallback,
                                router.threads()
                            );
                        } else {
                            println!("op-router: disabled (naive interpreter)");
                        }
                        println!(
                            "done: {} steps, {:.1} steps/s, learned={}",
                            report.losses.len(),
                            report.steps_per_sec,
                            report.learned()
                        );
                    }
                    Err(e) => {
                        eprintln!("training failed: {e:#}");
                        std::process::exit(1);
                    }
                },
                Err(e) => {
                    eprintln!("{e:#}");
                    std::process::exit(1);
                }
            }
        }
        _ => {
            println!("{USAGE}");
        }
    }
}
