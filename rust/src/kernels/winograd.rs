//! Winograd F(2×2, 3×3) convolution — the `winograd` baseline.
//!
//! Restrictions exactly as in the paper (§5.1): 3×3 filters, unit stride
//! only (MKL-DNN's Winograd does not support strided convolution), needs
//! workspace memory, and the transform erases dynamic sparsity. The
//! arithmetic reduction is 36/16 = 2.25× fewer MACs in the elementwise
//! stage vs direct's 9 MACs per output (plus transform overhead).

use super::{ConvConfig, KernelStats};
use crate::tensor::{ActTensor, FilterTensor};
use crate::V;

/// Whether the Winograd kernel applies to a configuration.
pub fn applicable(cfg: &ConvConfig) -> bool {
    cfg.r == 3 && cfg.s == 3 && cfg.stride_o == 1 && cfg.stride_p == 1
}

/// Filter transform: `U = G_w · g · G_wᵀ` for each (k, c); g is 3×3,
/// U is 4×4 with G_w = [[1,0,0],[.5,.5,.5],[.5,-.5,.5],[0,0,1]].
fn filter_transform(g3: &[f32; 9]) -> [f32; 16] {
    let gw = [[1.0, 0.0, 0.0], [0.5, 0.5, 0.5], [0.5, -0.5, 0.5], [0.0, 0.0, 1.0f32]];
    // t = G_w (4x3) · g (3x3) → 4x3
    let mut t = [[0.0f32; 3]; 4];
    for i in 0..4 {
        for j in 0..3 {
            for p in 0..3 {
                t[i][j] += gw[i][p] * g3[p * 3 + j];
            }
        }
    }
    // u = t (4x3) · G_wᵀ (3x4) → 4x4
    let mut u = [0.0f32; 16];
    for i in 0..4 {
        for j in 0..4 {
            let mut acc = 0.0;
            for p in 0..3 {
                acc += t[i][p] * gw[j][p];
            }
            u[i * 4 + j] = acc;
        }
    }
    u
}

/// Input transform: `V = Bᵀ · d · B`; d is a 4×4 tile,
/// Bᵀ = [[1,0,-1,0],[0,1,1,0],[0,-1,1,0],[0,1,0,-1]].
fn input_transform(d4: &[f32; 16]) -> [f32; 16] {
    let bt = [[1.0, 0.0, -1.0, 0.0], [0.0, 1.0, 1.0, 0.0], [0.0, -1.0, 1.0, 0.0], [0.0, 1.0, 0.0, -1.0f32]];
    let mut t = [[0.0f32; 4]; 4];
    for i in 0..4 {
        for j in 0..4 {
            for p in 0..4 {
                t[i][j] += bt[i][p] * d4[p * 4 + j];
            }
        }
    }
    let mut v = [0.0f32; 16];
    for i in 0..4 {
        for j in 0..4 {
            let mut acc = 0.0;
            for p in 0..4 {
                acc += t[i][p] * bt[j][p];
            }
            v[i * 4 + j] = acc;
        }
    }
    v
}

/// Output transform: `y = Aᵀ · m · A`; m is 4×4, y is 2×2,
/// Aᵀ = [[1,1,1,0],[0,1,-1,-1]].
fn output_transform(m4: &[f32; 16]) -> [f32; 4] {
    let at = [[1.0, 1.0, 1.0, 0.0], [0.0, 1.0, -1.0, -1.0f32]];
    let mut t = [[0.0f32; 4]; 2];
    for i in 0..2 {
        for j in 0..4 {
            for p in 0..4 {
                t[i][j] += at[i][p] * m4[p * 4 + j];
            }
        }
    }
    let mut y = [0.0f32; 4];
    for i in 0..2 {
        for j in 0..2 {
            let mut acc = 0.0;
            for p in 0..4 {
                acc += t[i][p] * at[j][p];
            }
            y[i * 2 + j] = acc;
        }
    }
    y
}

/// Winograd F(2×2,3×3) forward convolution. Requires [`applicable`].
pub fn fwd(
    cfg: &ConvConfig,
    d: &ActTensor,
    g: &FilterTensor,
    y: &mut ActTensor,
    stats: &mut KernelStats,
) {
    assert!(applicable(cfg), "winograd requires 3x3 stride-1");
    cfg.validate().expect("invalid conv config");
    let (oh, ow) = (cfg.out_h(), cfg.out_w());
    let tiles_y = oh.div_ceil(2);
    let tiles_x = ow.div_ceil(2);

    // Pre-transform all filters: U[k][c] (4x4).
    let mut u = vec![[0.0f32; 16]; cfg.k * cfg.c];
    for k in 0..cfg.k {
        for c in 0..cfg.c {
            let mut g3 = [0.0f32; 9];
            for s in 0..3 {
                for r in 0..3 {
                    g3[s * 3 + r] = g.get(k, c, s, r);
                }
            }
            u[k * cfg.c + c] = filter_transform(&g3);
        }
    }

    for i in 0..cfg.n {
        for ty in 0..tiles_y {
            for tx in 0..tiles_x {
                // Input tile origin in input coords.
                let y0 = (ty * 2) as isize - cfg.pad_h as isize;
                let x0 = (tx * 2) as isize - cfg.pad_w as isize;
                // Transform the input tile per channel, then accumulate the
                // elementwise products per output channel.
                let mut m = vec![[0.0f32; 16]; cfg.k];
                for c in 0..cfg.c {
                    let mut d4 = [0.0f32; 16];
                    for dy_ in 0..4 {
                        for dx in 0..4 {
                            let yy = y0 + dy_ as isize;
                            let xx = x0 + dx as isize;
                            if yy >= 0 && yy < cfg.h as isize && xx >= 0 && xx < cfg.w as isize {
                                d4[dy_ * 4 + dx] = d.get(i, c, yy as usize, xx as usize);
                            }
                        }
                    }
                    let v = input_transform(&d4);
                    for k in 0..cfg.k {
                        let uk = &u[k * cfg.c + c];
                        let mk = &mut m[k];
                        for e in 0..16 {
                            mk[e] += uk[e] * v[e];
                        }
                    }
                }
                for k in 0..cfg.k {
                    let out = output_transform(&m[k]);
                    for dy_ in 0..2 {
                        for dx in 0..2 {
                            let oy = ty * 2 + dy_;
                            let ox = tx * 2 + dx;
                            if oy < oh && ox < ow {
                                y.set(i, k, oy, ox, out[dy_ * 2 + dx]);
                            }
                        }
                    }
                }
            }
        }
    }
    stats_only(cfg, stats);
}

/// Data-independent cost accounting for Winograd (the transform erases
/// sparsity, so cost never depends on the input values).
pub fn stats_only(cfg: &ConvConfig, stats: &mut KernelStats) {
    let (oh, ow) = (cfg.out_h(), cfg.out_w());
    let tiles = (cfg.n * oh.div_ceil(2) * ow.div_ceil(2)) as u64;
    // Elementwise stage: each of the 16 Winograd-space points is one V-wide
    // FMA over K → tiles · C · (K/V) · 16 vector FMAs.
    let kv = (cfg.k as u64).div_ceil(V as u64);
    let elementwise = tiles * cfg.c as u64 * kv * 16;
    stats.fma_vec += elementwise;
    // Input transform: 32 adds per (tile, c); output transform: 24 adds per
    // (tile, k) — vectorized → /V vector FP ops.
    let in_tf = tiles * (cfg.c as u64) * 32 / V as u64;
    let out_tf = tiles * (cfg.k as u64) * 24 / V as u64;
    stats.vec_fp_ops += in_tf + out_tf;
    // Memory: input tiles read (overlapping 4x4 reads = 4 vectors per tile
    // per C-tile), U streamed per tile, M workspace write+read, Y write.
    let cb = (cfg.c / V) as u64;
    stats.loads_in += tiles * cb * 16;
    stats.loads_flt += elementwise; // U operand from memory
    stats.loads_out += tiles * kv * 16;
    stats.stores_out += tiles * kv * (16 + 4);
    stats.sweeps += 1;
    stats.filter_bytes_per_sweep =
        stats.filter_bytes_per_sweep.max((cfg.k * cfg.c * 16 * 4) as u64);
}

#[cfg(test)]
mod tests {
    use super::super::reference;
    use super::*;
    use crate::tensor::allclose;
    use crate::util::prng::Xorshift;

    #[test]
    fn matches_reference_even_dims() {
        let cfg = ConvConfig::square(2, 16, 32, 8, 3, 1);
        let mut rng = Xorshift::new(21);
        let mut d = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
        d.fill_uniform(&mut rng, -1.0, 1.0);
        let mut g = FilterTensor::zeros(cfg.k, cfg.c, 3, 3);
        g.fill_uniform(&mut rng, -0.5, 0.5);
        let mut y = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
        let mut st = KernelStats::new();
        fwd(&cfg, &d, &g, &mut y, &mut st);
        let yref = reference::conv_fwd(&cfg, &d.to_nchw(), &g.to_kcsr());
        assert!(allclose(&y.to_nchw(), &yref, 1e-3, 1e-4));
    }

    #[test]
    fn matches_reference_odd_dims() {
        // odd output size exercises partial tiles
        let cfg = ConvConfig::square(1, 16, 16, 7, 3, 1);
        let mut rng = Xorshift::new(23);
        let mut d = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
        d.fill_uniform(&mut rng, -1.0, 1.0);
        let mut g = FilterTensor::zeros(cfg.k, cfg.c, 3, 3);
        g.fill_uniform(&mut rng, -0.5, 0.5);
        let mut y = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
        let mut st = KernelStats::new();
        fwd(&cfg, &d, &g, &mut y, &mut st);
        let yref = reference::conv_fwd(&cfg, &d.to_nchw(), &g.to_kcsr());
        assert!(allclose(&y.to_nchw(), &yref, 1e-3, 1e-4));
    }

    #[test]
    fn not_applicable_to_strided_or_1x1() {
        assert!(!applicable(&ConvConfig::square(1, 16, 16, 8, 3, 2)));
        assert!(!applicable(&ConvConfig::square(1, 16, 16, 8, 1, 1)));
        assert!(applicable(&ConvConfig::square(1, 16, 16, 8, 3, 1)));
    }

    #[test]
    fn arithmetic_reduction_vs_direct() {
        // Winograd's elementwise stage must use ~2.25x fewer MACs than
        // direct's 9 per output (ignoring transforms).
        let cfg = ConvConfig::square(16, 256, 256, 56, 3, 1);
        let mut st = KernelStats::new();
        stats_only(&cfg, &mut st);
        let direct_fmas = cfg.fwd_vec_fmas() as f64;
        let ratio = direct_fmas / st.fma_vec as f64;
        assert!(
            (ratio - 2.25).abs() < 0.05,
            "expected ~2.25x fewer elementwise FMAs, got {ratio}"
        );
    }
}
