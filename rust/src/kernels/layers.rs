//! Non-convolution layer substrates needed to run whole networks:
//! ReLU (the sparsity source), BatchNorm (the sparsity destroyer — §2.3),
//! pooling, fully-connected, and softmax cross-entropy loss.

use crate::tensor::ActTensor;
use crate::util::prng::Xorshift;

/// ReLU forward in place; returns the induced sparsity of the output.
pub fn relu_fwd(x: &mut ActTensor) -> f64 {
    let mut zeros = 0usize;
    for v in x.data_mut().iter_mut() {
        if *v <= 0.0 {
            *v = 0.0;
            zeros += 1;
        }
    }
    zeros as f64 / x.len() as f64
}

/// ReLU backward: `dX = dY ⊙ [Y > 0]` given the *forward output* `y`
/// (equivalent to gating on the pre-activation sign; f'(0) = 0 per the
/// paper's footnote). The gradient inherits y's zero pattern — the dynamic
/// sparsity BWI exploits.
pub fn relu_bwd(y: &ActTensor, dy: &mut ActTensor) {
    assert_eq!(y.len(), dy.len());
    for (g, &o) in dy.data_mut().iter_mut().zip(y.data()) {
        if o <= 0.0 {
            *g = 0.0;
        }
    }
}

/// Per-channel BatchNorm statistics.
#[derive(Debug, Clone)]
pub struct BnParams {
    pub gamma: Vec<f32>,
    pub beta: Vec<f32>,
}

impl BnParams {
    pub fn identity(c: usize) -> BnParams {
        BnParams { gamma: vec![1.0; c], beta: vec![0.0; c] }
    }
}

/// BatchNorm forward (training mode: batch statistics). Returns per-channel
/// (mean, inv_std) for the backward pass.
pub fn batchnorm_fwd(x: &mut ActTensor, p: &BnParams, eps: f32) -> (Vec<f32>, Vec<f32>) {
    let c = x.c;
    let per = (x.n * x.h * x.w) as f32;
    let mut mean = vec![0.0f32; c];
    let mut var = vec![0.0f32; c];
    for i in 0..x.n {
        for ch in 0..c {
            for y in 0..x.h {
                for xx in 0..x.w {
                    mean[ch] += x.get(i, ch, y, xx);
                }
            }
        }
    }
    for m in mean.iter_mut() {
        *m /= per;
    }
    for i in 0..x.n {
        for ch in 0..c {
            for y in 0..x.h {
                for xx in 0..x.w {
                    let d = x.get(i, ch, y, xx) - mean[ch];
                    var[ch] += d * d;
                }
            }
        }
    }
    let inv_std: Vec<f32> = var.iter().map(|v| 1.0 / (v / per + eps).sqrt()).collect();
    for i in 0..x.n {
        for ch in 0..c {
            for y in 0..x.h {
                for xx in 0..x.w {
                    let v = (x.get(i, ch, y, xx) - mean[ch]) * inv_std[ch] * p.gamma[ch]
                        + p.beta[ch];
                    x.set(i, ch, y, xx, v);
                }
            }
        }
    }
    (mean, inv_std)
}

/// 2×2 max pooling with stride 2.
pub fn maxpool2_fwd(x: &ActTensor) -> ActTensor {
    let (oh, ow) = (x.h / 2, x.w / 2);
    let mut y = ActTensor::zeros(x.n, x.c, oh, ow);
    for i in 0..x.n {
        for c in 0..x.c {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut m = f32::NEG_INFINITY;
                    for dy in 0..2 {
                        for dx in 0..2 {
                            m = m.max(x.get(i, c, oy * 2 + dy, ox * 2 + dx));
                        }
                    }
                    y.set(i, c, oy, ox, m);
                }
            }
        }
    }
    y
}

/// Global average pooling → `[N][C]`.
pub fn global_avgpool(x: &ActTensor) -> Vec<Vec<f32>> {
    let per = (x.h * x.w) as f32;
    (0..x.n)
        .map(|i| {
            (0..x.c)
                .map(|c| {
                    let mut s = 0.0;
                    for y in 0..x.h {
                        for xx in 0..x.w {
                            s += x.get(i, c, y, xx);
                        }
                    }
                    s / per
                })
                .collect()
        })
        .collect()
}

/// Fully-connected forward: `logits[i][o] = Σ_f x[i][f]·w[o][f] + b[o]`.
pub fn fc_fwd(x: &[Vec<f32>], w: &[Vec<f32>], b: &[f32]) -> Vec<Vec<f32>> {
    x.iter()
        .map(|xi| {
            w.iter()
                .zip(b)
                .map(|(wo, bo)| xi.iter().zip(wo).map(|(a, b)| a * b).sum::<f32>() + bo)
                .collect()
        })
        .collect()
}

/// Softmax cross-entropy: returns (mean loss, dLogits).
pub fn softmax_xent(logits: &[Vec<f32>], labels: &[usize]) -> (f32, Vec<Vec<f32>>) {
    let n = logits.len() as f32;
    let mut loss = 0.0f32;
    let mut grads = Vec::with_capacity(logits.len());
    for (row, &lab) in logits.iter().zip(labels) {
        let m = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let exps: Vec<f32> = row.iter().map(|v| (v - m).exp()).collect();
        let z: f32 = exps.iter().sum();
        let probs: Vec<f32> = exps.iter().map(|e| e / z).collect();
        loss += -(probs[lab].max(1e-12)).ln();
        let g: Vec<f32> = probs
            .iter()
            .enumerate()
            .map(|(j, p)| (p - if j == lab { 1.0 } else { 0.0 }) / n)
            .collect();
        grads.push(g);
    }
    (loss / n, grads)
}

/// Synthetic labeled batch generator (CIFAR-like) used by examples/tests.
///
/// The class signal is a per-class *channel signature* (deterministic ±
/// pattern over channels) so it survives the model's global average
/// pooling; spatial structure + noise make the convs do real work.
pub fn synthetic_batch(
    rng: &mut Xorshift,
    n: usize,
    c: usize,
    hw: usize,
    classes: usize,
) -> (ActTensor, Vec<usize>) {
    let mut x = ActTensor::zeros(n, c, hw, hw);
    let labels: Vec<usize> = (0..n).map(|_| rng.below(classes)).collect();
    // deterministic per-class channel signatures
    let signatures: Vec<Vec<f32>> = (0..classes)
        .map(|lab| {
            let mut crng = Xorshift::new(0x516E ^ (lab as u64).wrapping_mul(0x9E3779B97F4A7C15));
            (0..c).map(|_| if crng.bernoulli(0.5) { 1.0 } else { -1.0 }).collect()
        })
        .collect();
    for (i, &lab) in labels.iter().enumerate() {
        let sig = &signatures[lab];
        for ch in 0..c {
            for y in 0..hw {
                for xx in 0..hw {
                    // spatial texture (checker ripple) + class signature + noise
                    let tex = (((y + xx) % 4) as f32 / 4.0) - 0.375;
                    x.set(
                        i,
                        ch,
                        y,
                        xx,
                        0.8 * sig[ch] + 0.4 * tex + 0.3 * (rng.next_f32() - 0.5),
                    );
                }
            }
        }
    }
    (x, labels)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Xorshift;

    #[test]
    fn relu_zeroes_negatives_and_reports_sparsity() {
        let mut rng = Xorshift::new(3);
        let mut x = ActTensor::zeros(2, 16, 4, 4);
        x.fill_uniform(&mut rng, -1.0, 1.0);
        let s = relu_fwd(&mut x);
        assert!(x.data().iter().all(|&v| v >= 0.0));
        assert!((s - 0.5).abs() < 0.1, "sparsity={s}");
        assert!((x.sparsity() - s).abs() < 1e-9);
    }

    #[test]
    fn relu_bwd_gates_gradient_with_output_pattern() {
        let mut rng = Xorshift::new(5);
        let mut x = ActTensor::zeros(1, 16, 4, 4);
        x.fill_uniform(&mut rng, -1.0, 1.0);
        relu_fwd(&mut x);
        let mut dy = ActTensor::zeros(1, 16, 4, 4);
        dy.fill_uniform(&mut rng, -1.0, 1.0);
        relu_bwd(&x, &mut dy);
        for (g, o) in dy.data().iter().zip(x.data()) {
            if *o == 0.0 {
                assert_eq!(*g, 0.0);
            }
        }
        // gradient sparsity >= activation sparsity
        assert!(dy.sparsity() >= x.sparsity() - 1e-9);
    }

    #[test]
    fn batchnorm_normalizes() {
        let mut rng = Xorshift::new(7);
        let mut x = ActTensor::zeros(4, 16, 6, 6);
        x.fill_uniform(&mut rng, 2.0, 6.0);
        batchnorm_fwd(&mut x, &BnParams::identity(16), 1e-5);
        // per-channel mean ~0, var ~1
        let per = (4 * 6 * 6) as f32;
        for c in 0..16 {
            let mut m = 0.0;
            for i in 0..4 {
                for y in 0..6 {
                    for xx in 0..6 {
                        m += x.get(i, c, y, xx);
                    }
                }
            }
            m /= per;
            assert!(m.abs() < 1e-4, "c={c} mean={m}");
        }
    }

    #[test]
    fn batchnorm_destroys_relu_sparsity_structure() {
        // After BN, previous zeros are shifted — the paper's §2.3 point.
        let mut rng = Xorshift::new(9);
        let mut x = ActTensor::zeros(4, 16, 6, 6);
        x.fill_relu_sparse(&mut rng, 0.6);
        let before = x.sparsity();
        batchnorm_fwd(&mut x, &BnParams::identity(16), 1e-5);
        assert!(before > 0.5);
        assert!(x.sparsity() < 0.01, "BN should wipe exact zeros");
    }

    #[test]
    fn maxpool_shapes_and_values() {
        let mut x = ActTensor::zeros(1, 16, 4, 4);
        x.set(0, 0, 1, 1, 9.0);
        let y = maxpool2_fwd(&x);
        assert_eq!((y.h, y.w), (2, 2));
        assert_eq!(y.get(0, 0, 0, 0), 9.0);
    }

    #[test]
    fn softmax_xent_gradient_sums_to_zero() {
        let logits = vec![vec![1.0, 2.0, 0.5], vec![0.1, 0.1, 0.1]];
        let (loss, g) = softmax_xent(&logits, &[1, 0]);
        assert!(loss > 0.0);
        for row in &g {
            let s: f32 = row.iter().sum();
            assert!(s.abs() < 1e-6);
        }
    }

    #[test]
    fn fc_identity() {
        let x = vec![vec![1.0, 2.0]];
        let w = vec![vec![1.0, 0.0], vec![0.0, 1.0]];
        let b = vec![0.5, -0.5];
        let out = fc_fwd(&x, &w, &b);
        assert_eq!(out, vec![vec![1.5, 1.5]]);
    }

    #[test]
    fn synthetic_batch_learnable_structure() {
        let mut rng = Xorshift::new(11);
        let (x, labels) = synthetic_batch(&mut rng, 8, 16, 16, 4);
        assert_eq!(labels.len(), 8);
        assert!(labels.iter().all(|&l| l < 4));
        assert_eq!((x.n, x.c, x.h, x.w), (8, 16, 16, 16));
    }
}
