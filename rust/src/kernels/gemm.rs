//! Blocked, SIMD-dispatched, optionally multi-threaded GEMM.
//!
//! Promoted out of `im2col.rs` (ISSUE 6) so the op router can serve
//! `Op::Dot` with the same kernel the im2col baseline uses. Layout is
//! row-major throughout: `c[m][n] += a[m][k] · b[k][n]`.
//!
//! Structure: the output rows are split into `MB`-row panels; within a
//! panel the contraction dimension is walked in `KB`-sized blocks so the
//! streamed `b` panel stays in cache across the panel's rows, and the
//! inner kernel is j-vectorized through [`simd::Backend::axpy_v`]
//! (contiguous in `b` and `c`) with an `a == 0.0` skip — the paper's
//! dynamic-sparsity short-circuit applies to GEMM operands too.
//!
//! Determinism contract: for every output row the contraction is
//! accumulated in strictly ascending `p` order, *independent of how rows
//! are grouped into panels or distributed over threads*. A serial run
//! ([`gemm_with`]) and a parallel run ([`gemm_parallel`]) over any thread
//! count are therefore **bit-identical** — pinned by
//! `miri_gemm_parallel_matches_serial_bitwise` and the `op_route_parity`
//! proptests. Against the naive triple loop the result is allclose, not
//! bit-equal: `axpy_v` contracts multiply-add to a single-rounding FMA.

use super::simd::{self, Backend};
use crate::util::threadpool::ThreadPool;
use crate::V;

/// Rows per output panel (both the serial blocking factor and the unit of
/// parallel work distribution).
pub const MB: usize = 32;

/// Contraction-dimension block: `KB` rows of `b` (`KB · n` floats) are
/// re-streamed across one panel's rows before moving on.
const KB: usize = 128;

/// The panel kernel: accumulate `rows` (output rows `row0..row0+rows.len()`
/// of `c`) against the full contraction dimension. Per-row `p` order is
/// globally ascending — see the module docs' determinism contract.
fn gemm_panel_rows(
    bk: Backend,
    n: usize,
    k: usize,
    a: &[f32],
    b: &[f32],
    row0: usize,
    rows: &mut [&mut [f32]],
) {
    for p0 in (0..k).step_by(KB.max(1)) {
        let p1 = (p0 + KB).min(k);
        for (r, crow) in rows.iter_mut().enumerate() {
            let arow = &a[(row0 + r) * k..(row0 + r + 1) * k];
            for (p, &av) in arow.iter().enumerate().take(p1).skip(p0) {
                if av == 0.0 {
                    continue;
                }
                let brow = &b[p * n..(p + 1) * n];
                let mut j = 0;
                while j + V <= n {
                    bk.axpy_v(&mut crow[j..j + V], av, &brow[j..j + V]);
                    j += V;
                }
                while j < n {
                    crow[j] = brow[j].mul_add(av, crow[j]);
                    j += 1;
                }
            }
        }
    }
}

/// Serial blocked GEMM with the process-wide dispatched backend — the
/// drop-in replacement for the old `im2col::gemm` (accumulates into `c`).
pub fn gemm(m: usize, n: usize, k: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    gemm_with(simd::dispatch(), m, n, k, a, b, c);
}

/// Serial blocked GEMM with an explicit backend — the pinned reference the
/// parallel path must match bit for bit.
pub fn gemm_with(bk: Backend, m: usize, n: usize, k: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    let mut rows: Vec<&mut [f32]> = c.chunks_mut(n).collect();
    for (pi, panel) in rows.chunks_mut(MB).enumerate() {
        gemm_panel_rows(bk, n, k, a, b, pi * MB, panel);
    }
}

/// Parallel blocked GEMM: `MB`-row panels are distributed over the
/// persistent pool's workers (dynamic work-stealing cursor, deterministic
/// panel boundaries). Bit-identical to [`gemm_with`] with the same backend
/// at any thread count.
pub fn gemm_parallel(
    pool: &ThreadPool,
    bk: Backend,
    m: usize,
    n: usize,
    k: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
) {
    gemm_parallel_chunks(pool, bk, m, n, k, a, b, c, m.div_ceil(MB));
}

/// [`gemm_parallel`] with an explicit work-distribution chunk count (the
/// selector's measured-cost GEMM policy picks it per shape). The chunk
/// count only changes how whole output rows are *grouped* across workers
/// — per-row contraction order is untouched — so every chunk count is
/// bit-identical to the serial kernel.
#[allow(clippy::too_many_arguments)]
pub fn gemm_parallel_chunks(
    pool: &ThreadPool,
    bk: Backend,
    m: usize,
    n: usize,
    k: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    chunks: usize,
) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    let mut rows: Vec<&mut [f32]> = c.chunks_mut(n).collect();
    let chunks = chunks.clamp(1, m);
    pool.for_chunk_slices(&mut rows, chunks, |_ci, start, chunk| {
        gemm_panel_rows(bk, n, k, a, b, start, chunk);
    });
}

/// Pack the transpose: `out[c][r] = src[r][c]` for a row-major
/// `rows × cols` matrix. The op router uses this to normalize `dot`
/// contraction layouts onto the row-major `a[m][k] · b[k][n]` kernel.
pub fn pack_transpose(src: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    assert_eq!(src.len(), rows * cols);
    let mut out = vec![0.0f32; src.len()];
    for r in 0..rows {
        for c in 0..cols {
            out[c * rows + r] = src[r * cols + c];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::allclose;
    use crate::util::prng::Xorshift;

    fn fill(rng: &mut Xorshift, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.range_f32(-1.0, 1.0)).collect()
    }

    #[test]
    fn gemm_matches_naive_triple_loop() {
        let (m, n, k) = (7, 33, 19);
        let mut rng = Xorshift::new(3);
        let a = fill(&mut rng, m * k);
        let b = fill(&mut rng, k * n);
        let mut c = vec![0.0f32; m * n];
        gemm(m, n, k, &a, &b, &mut c);
        let mut cref = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                for p in 0..k {
                    cref[i * n + j] += a[i * k + p] * b[p * n + j];
                }
            }
        }
        assert!(allclose(&c, &cref, 1e-4, 1e-5));
    }

    #[test]
    fn gemm_accumulates_into_c() {
        let mut rng = Xorshift::new(5);
        let a = fill(&mut rng, 2 * 3);
        let b = fill(&mut rng, 3 * 4);
        let mut once = vec![0.0f32; 2 * 4];
        gemm(2, 4, 3, &a, &b, &mut once);
        let mut twice = once.clone();
        gemm(2, 4, 3, &a, &b, &mut twice);
        for (t, o) in twice.iter().zip(&once) {
            assert!((t - 2.0 * o).abs() < 1e-5);
        }
    }

    #[test]
    fn miri_gemm_parallel_matches_serial_bitwise() {
        // Reduced geometry; n = 17 exercises the scalar tail, m spans
        // several panel/chunk boundary cases relative to the pool width.
        let bk = Backend::scalar();
        let pool = ThreadPool::new(2);
        let mut rng = Xorshift::new(11);
        for m in [1usize, 2, 5, 8] {
            let (n, k) = (17usize, 9usize);
            let a = fill(&mut rng, m * k);
            let b = fill(&mut rng, k * n);
            let mut serial = vec![0.0f32; m * n];
            gemm_with(bk, m, n, k, &a, &b, &mut serial);
            let mut par = vec![0.0f32; m * n];
            gemm_parallel(&pool, bk, m, n, k, &a, &b, &mut par);
            let sb: Vec<u32> = serial.iter().map(|v| v.to_bits()).collect();
            let pb: Vec<u32> = par.iter().map(|v| v.to_bits()).collect();
            assert_eq!(sb, pb, "m={m}");
        }
    }

    #[test]
    fn miri_gemm_parallel_chunks_bit_identical_for_any_chunk_count() {
        let bk = Backend::scalar();
        let pool = ThreadPool::new(2);
        let mut rng = Xorshift::new(13);
        let (m, n, k) = (6usize, 17usize, 9usize);
        let a = fill(&mut rng, m * k);
        let b = fill(&mut rng, k * n);
        let mut serial = vec![0.0f32; m * n];
        gemm_with(bk, m, n, k, &a, &b, &mut serial);
        let sb: Vec<u32> = serial.iter().map(|v| v.to_bits()).collect();
        for chunks in [1usize, 2, 3, 6, 64] {
            let mut par = vec![0.0f32; m * n];
            gemm_parallel_chunks(&pool, bk, m, n, k, &a, &b, &mut par, chunks);
            let pb: Vec<u32> = par.iter().map(|v| v.to_bits()).collect();
            assert_eq!(sb, pb, "chunks={chunks}");
        }
    }

    #[test]
    fn pack_transpose_roundtrip() {
        let src: Vec<f32> = (0..6).map(|i| i as f32).collect();
        let t = pack_transpose(&src, 2, 3); // 2x3 -> 3x2
        assert_eq!(t, vec![0.0, 3.0, 1.0, 4.0, 2.0, 5.0]);
        assert_eq!(pack_transpose(&t, 3, 2), src);
    }

    #[test]
    fn zero_sized_gemm_is_a_no_op() {
        let mut c: Vec<f32> = Vec::new();
        gemm(0, 4, 3, &[], &[0.0; 12], &mut []);
        gemm(2, 0, 3, &[0.0; 6], &[], &mut c);
    }
}
