//! SparseTrain forward propagation (Algorithms 2 + 3 of the paper).
//!
//! Structure per §3.2:
//! * **output parallelism** at output-row × K-tile granularity (§3.2.2):
//!   the loop nest here is the per-task body; the coordinator parallelizes
//!   over `(i, oy, qb)` tasks;
//! * **vectorized zero-checking** along the input-channel dimension: one
//!   vector compare per input V-vector produces a lane mask (§3.2.1),
//!   executed as one `vcmpps` + mask extract by the dispatched
//!   [`Backend`];
//! * **mask-loop skipping** (Algorithm 3): popcount + trailing-zero-count
//!   iteration over set lanes, instead of one branch per lane (§3.2.4);
//!   each surviving lane issues its `taps·Q/V` FMA group through
//!   [`Backend::axpy_v`] — one V-wide `vfmadd` per group element;
//! * **register-budget tiling**: output channels tiled by `Q` from
//!   [`regalloc::plan_fwd`] so `T = R·Q/V` accumulators stay in registers
//!   (§3.2.3); the row-sweep accumulator here is a per-worker
//!   [`Scratch`] buffer the compiler keeps in vector registers / L1 —
//!   reused across tasks, so the hot path allocates nothing.
//!
//! The kernel is *functional* (bit-exact against the dense direct kernel —
//! skipping only elides multiplications by exact zeros) and *accounted*
//! (issued vs skipped FMAs, mask statistics for the mispredict model).

use super::direct::SweepGeom;
use super::regalloc::{plan_fwd, RegPlan};
use super::simd::{self, Backend};
use super::{ConvConfig, KernelStats, Scratch, SkipMode};
use crate::tensor::{ActTensor, FilterTensor, RowTileMut};
use crate::V;

/// SparseTrain FWD over the tiled layouts. `y` must be zero-initialized.
/// Uses the process-wide dispatched [`Backend`] and a fresh [`Scratch`].
///
/// The serial driver iterates the *same* per-task views the parallel
/// scheduler distributes ([`ActTensor::par_row_tiles_mut`]), in the same
/// `(i, oy, qb)` order — so parallel execution is bit-identical by
/// construction, not by a separate code path.
pub fn fwd(
    cfg: &ConvConfig,
    d: &ActTensor,
    g: &FilterTensor,
    y: &mut ActTensor,
    mode: SkipMode,
    stats: &mut KernelStats,
) {
    fwd_with(cfg, d, g, y, mode, simd::dispatch(), &mut Scratch::new(), stats);
}

/// [`fwd`] with an explicit backend and reusable scratch — the zero-alloc
/// entry point the wallclock harness and the parity suite drive.
#[allow(clippy::too_many_arguments)]
pub fn fwd_with(
    cfg: &ConvConfig,
    d: &ActTensor,
    g: &FilterTensor,
    y: &mut ActTensor,
    mode: SkipMode,
    bk: Backend,
    scratch: &mut Scratch,
    stats: &mut KernelStats,
) {
    cfg.validate().expect("invalid conv config");
    debug_assert_eq!((d.n, d.c, d.h, d.w), (cfg.n, cfg.c, cfg.h, cfg.w));
    debug_assert_eq!((g.k, g.c, g.s, g.r), (cfg.k, cfg.c, cfg.s, cfg.r));
    debug_assert_eq!((y.n, y.c, y.h, y.w), (cfg.n, cfg.k, cfg.out_h(), cfg.out_w()));

    let plan = plan_fwd(cfg.k, cfg.r);
    let geom = SweepGeom::fwd(cfg);
    for view in y.par_row_tiles_mut(plan.q / V).iter_mut() {
        fwd_task(cfg, d, g, view, mode, &plan, &geom, bk, scratch, stats);
    }
    stats.filter_bytes_per_sweep =
        stats.filter_bytes_per_sweep.max((cfg.s * cfg.r * plan.q * V * 4) as u64);
}

/// The per-task body (one output row × one Q tile of output channels for
/// one image): exactly the work unit the coordinator schedules (§3.2.2).
///
/// The task writes only through its own [`RowTileMut`] view — the owned
/// disjoint slice of `y` for `(view.i, view.y, view.qb)` — so the borrow
/// checker guarantees two tasks can never write the same memory. The
/// driver passes the register `plan` and sweep `geom` it already computed
/// (hoisted out of the hot path) plus the worker's reusable `scratch`.
#[allow(clippy::too_many_arguments)]
pub fn fwd_task(
    cfg: &ConvConfig,
    d: &ActTensor,
    g: &FilterTensor,
    view: &mut RowTileMut<'_>,
    mode: SkipMode,
    plan: &RegPlan,
    geom: &SweepGeom,
    bk: Backend,
    scratch: &mut Scratch,
    stats: &mut KernelStats,
) {
    debug_assert_eq!(*plan, plan_fwd(cfg.k, cfg.r), "plan must come from the driver's plan_fwd");
    let qv = plan.q / V;
    debug_assert_eq!(view.tiles(), qv, "view tiling must match the register plan");
    let (i, oy, qb) = (view.i, view.y, view.qb);
    debug_assert_eq!(geom.taps.len(), cfg.w, "geom must match the layer width");
    let cb_count = cfg.c / V;
    let ow = cfg.out_w();

    // Row-sweep accumulator: qv output vectors × ow columns. The paper keeps
    // T = R·Q/V of these in zmm registers with cyclic renaming; a reused
    // scratch buffer of the live row gives the compiler the same freedom
    // while staying functional for any W (and allocation-free per task).
    // acc_uninit: the row load below overwrites every element.
    let acc = scratch.acc_uninit(ow * qv * V);

    for j in 0..qv {
        // load existing output row (zero on entry, but the sweep protocol
        // loads/stores once per row sweep — accounted below); whole-row
        // memcpy beats per-vector copy_v calls here
        acc[j * ow * V..(j + 1) * ow * V].copy_from_slice(view.row(j));
    }

    for s in 0..cfg.s {
        let iy = oy as isize * cfg.stride_p as isize + s as isize - cfg.pad_h as isize;
        if iy < 0 || iy >= cfg.h as isize {
            continue;
        }
        let iy = iy as usize;
        for cb in 0..cb_count {
            sweep_row(cfg, d, g, acc, i, iy, s, qb, qv, cb, ow, mode, geom, bk, stats);
        }
    }

    for j in 0..qv {
        view.row_mut(j).copy_from_slice(&acc[j * ow * V..(j + 1) * ow * V]);
    }
    // Output row loaded once and stored once per task (cyclic renaming keeps
    // intermediate values in registers — §3.2.3).
    stats.loads_out += (ow * qv) as u64;
    stats.stores_out += (ow * qv) as u64;
}

/// One row sweep: scan input row `iy` of channel tile `cb`, skip zero lanes,
/// scatter into the row accumulator.
#[allow(clippy::too_many_arguments)]
#[inline]
fn sweep_row(
    cfg: &ConvConfig,
    d: &ActTensor,
    g: &FilterTensor,
    acc: &mut [f32],
    i: usize,
    iy: usize,
    s: usize,
    qb: usize,
    qv: usize,
    cb: usize,
    ow: usize,
    mode: SkipMode,
    geom: &SweepGeom,
    bk: Backend,
    stats: &mut KernelStats,
) {
    stats.sweeps += 1;
    stats.loads_in += cfg.w as u64;

    for x in 0..cfg.w {
        let dvec = d.vec_arr(i, cb, iy, x);
        let taps = &geom.taps[x];
        if taps.is_empty() {
            continue;
        }
        // Vectorized zero check: one vcmpps → lane mask (§3.2.1).
        let mask = bk.nonzero_mask(dvec);
        let nonzeros = mask.count_ones() as usize;
        stats.record_check(nonzeros);

        let t_here = (taps.len() * qv) as u64; // skippable FMAs per lane here
        stats.fma_vec_skipped += (V - nonzeros) as u64 * t_here;
        stats.fma_vec += nonzeros as u64 * t_here;

        match mode {
            SkipMode::Dense => {
                // process every lane unconditionally (zeros multiply through)
                for cv in 0..V {
                    fma_lane(g, acc, dvec[cv], qb, qv, cb, s, cv, taps, ow, bk);
                }
                // dense mode issues all FMAs: move the skipped count back
                stats.fma_vec += (V - nonzeros) as u64 * t_here;
                stats.fma_vec_skipped -= (V - nonzeros) as u64 * t_here;
            }
            SkipMode::PerLaneBranch => {
                // Algorithm 2: test each lane (a branch per lane).
                for cv in 0..V {
                    if mask & (1 << cv) != 0 {
                        fma_lane(g, acc, dvec[cv], qb, qv, cb, s, cv, taps, ow, bk);
                    }
                }
                stats.int_ops += V as u64; // one test per lane
            }
            SkipMode::MaskLoop => {
                // Algorithm 3: popcount + tzcnt loop; ~8 cheap integer ops
                // per set lane (pointer bumps, shifts, lea) per the paper.
                let mut m = mask;
                while m != 0 {
                    let cv = m.trailing_zeros() as usize;
                    fma_lane(g, acc, dvec[cv], qb, qv, cb, s, cv, taps, ow, bk);
                    m &= m - 1;
                }
                stats.int_ops += 2 + 8 * nonzeros as u64;
            }
        }
    }
}

/// All FMAs for one nonzero input lane: `taps.len() × qv` vector FMAs
/// ([`Backend::axpy_v`], the filter operand straight from (modeled)
/// memory).
///
/// Perf note (§Perf log): the filter offset is strength-reduced — for a
/// fixed (cb, s, cv) the offset is `kb·kb_stride + r·V² + base`, so the
/// inner loops use two adds instead of re-deriving the 5-term polynomial
/// per FMA group (the JIT kernels' lea/shift scheduling, §3.2.4).
#[inline(always)]
#[allow(clippy::too_many_arguments)]
fn fma_lane(
    g: &FilterTensor,
    acc: &mut [f32],
    dval: f32,
    qb: usize,
    qv: usize,
    cb: usize,
    s: usize,
    cv: usize,
    taps: &[(usize, usize)],
    ow: usize,
    bk: Backend,
) {
    let gdata = g.data();
    let kb_stride = g.c_blocks() * g.s * g.r * V * V;
    let lane_base = ((cb * g.s + s) * g.r) * V * V + cv * V;
    for j in 0..qv {
        let kb = qb * qv + j;
        let kb_base = kb * kb_stride + lane_base;
        let base = j * ow * V;
        for &(r, xo) in taps {
            let go = kb_base + r * V * V;
            let gvec = &gdata[go..go + V];
            let a = &mut acc[base + xo * V..base + xo * V + V];
            bk.axpy_v(a, dval, gvec);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::{direct, reference};
    use super::*;
    use crate::tensor::allclose;
    use crate::util::prng::Xorshift;

    fn sparse_setup(cfg: &ConvConfig, sparsity: f64, seed: u64) -> (ActTensor, FilterTensor) {
        let mut rng = Xorshift::new(seed);
        let mut d = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
        d.fill_relu_sparse(&mut rng, sparsity);
        let mut g = FilterTensor::zeros(cfg.k, cfg.c, cfg.s, cfg.r);
        g.fill_uniform(&mut rng, -0.5, 0.5);
        (d, g)
    }

    fn run_and_check(cfg: &ConvConfig, sparsity: f64, mode: SkipMode) -> KernelStats {
        let (d, g) = sparse_setup(cfg, sparsity, 101);
        let mut y = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
        let mut st = KernelStats::new();
        fwd(cfg, &d, &g, &mut y, mode, &mut st);
        let yref = reference::conv_fwd(cfg, &d.to_nchw(), &g.to_kcsr());
        assert!(allclose(&y.to_nchw(), &yref, 1e-4, 1e-5), "mode={mode:?}");
        st
    }

    #[test]
    #[cfg_attr(miri, ignore = "too slow under miri; miri_* tests cover the reduced set")]
    fn matches_reference_all_modes_3x3() {
        let cfg = ConvConfig::square(2, 32, 32, 8, 3, 1);
        for mode in [SkipMode::Dense, SkipMode::PerLaneBranch, SkipMode::MaskLoop] {
            run_and_check(&cfg, 0.6, mode);
        }
    }

    #[test]
    #[cfg_attr(miri, ignore = "too slow under miri; miri_* tests cover the reduced set")]
    fn matches_reference_strided() {
        let cfg = ConvConfig::square(2, 32, 32, 9, 3, 2);
        run_and_check(&cfg, 0.5, SkipMode::MaskLoop);
    }

    #[test]
    #[cfg_attr(miri, ignore = "too slow under miri; miri_* tests cover the reduced set")]
    fn matches_reference_1x1() {
        let cfg = ConvConfig::square(2, 64, 32, 7, 1, 1);
        run_and_check(&cfg, 0.5, SkipMode::MaskLoop);
    }

    #[test]
    #[cfg_attr(miri, ignore = "too slow under miri; miri_* tests cover the reduced set")]
    fn matches_reference_5x5() {
        let cfg = ConvConfig::square(1, 32, 32, 9, 5, 1);
        run_and_check(&cfg, 0.4, SkipMode::MaskLoop);
    }

    #[test]
    fn matches_dense_direct_bitexact_on_dense_input() {
        // On a zero-free input the sparse kernel performs exactly the same
        // FMAs in the same order as the dense kernel → bit-exact equality.
        let cfg = ConvConfig::square(1, 32, 32, 6, 3, 1);
        let (d, g) = sparse_setup(&cfg, 0.0, 5);
        let mut y1 = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
        let mut y2 = y1.clone();
        let mut s1 = KernelStats::new();
        let mut s2 = KernelStats::new();
        fwd(&cfg, &d, &g, &mut y1, SkipMode::MaskLoop, &mut s1);
        direct::fwd(&cfg, &d, &g, &mut y2, &mut s2);
        assert_eq!(y1.data(), y2.data());
        // and issues the same number of FMAs
        assert_eq!(s1.fma_vec, s2.fma_vec);
        assert_eq!(s1.fma_vec_skipped, 0);
    }

    #[test]
    #[cfg_attr(miri, ignore = "too slow under miri; miri_* tests cover the reduced set")]
    fn skip_fraction_tracks_sparsity() {
        let cfg = ConvConfig::square(2, 64, 64, 10, 3, 1);
        for target in [0.2, 0.5, 0.8] {
            let st = run_and_check(&cfg, target, SkipMode::MaskLoop);
            assert!(
                (st.skip_fraction() - target).abs() < 0.05,
                "target={target} skipped={}",
                st.skip_fraction()
            );
        }
    }

    #[test]
    fn zero_input_skips_everything() {
        let cfg = ConvConfig::square(1, 32, 32, 6, 3, 1);
        let (mut d, g) = sparse_setup(&cfg, 0.0, 7);
        d.fill_zero();
        let mut y = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
        let mut st = KernelStats::new();
        fwd(&cfg, &d, &g, &mut y, SkipMode::MaskLoop, &mut st);
        assert_eq!(st.fma_vec, 0);
        assert!(st.fma_vec_skipped > 0);
        assert!(y.data().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn mask_and_branch_modes_identical_results() {
        let cfg = ConvConfig::square(1, 32, 48, 7, 3, 1);
        let (d, g) = sparse_setup(&cfg, 0.55, 31);
        let mut ya = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
        let mut yb = ya.clone();
        let mut sa = KernelStats::new();
        let mut sb = KernelStats::new();
        fwd(&cfg, &d, &g, &mut ya, SkipMode::MaskLoop, &mut sa);
        fwd(&cfg, &d, &g, &mut yb, SkipMode::PerLaneBranch, &mut sb);
        assert_eq!(ya.data(), yb.data());
        assert_eq!(sa.fma_vec, sb.fma_vec);
        // mask loop executes fewer overhead ops at high sparsity
        assert_eq!(sa.zero_checks, sb.zero_checks);
    }

    #[test]
    #[cfg_attr(miri, ignore = "too slow under miri; miri_* tests cover the reduced set")]
    fn task_decomposition_equals_whole() {
        // Running the per-task body over all (i, oy, qb) views — in any
        // order — must equal fwd(). Reverse order exercises that tasks
        // really are independent.
        let cfg = ConvConfig::square(2, 32, 64, 6, 3, 1);
        let (d, g) = sparse_setup(&cfg, 0.5, 77);
        let plan = super::plan_fwd(cfg.k, cfg.r);
        let geom = SweepGeom::fwd(&cfg);
        let bk = simd::dispatch();
        let mut scratch = Scratch::new();
        let mut y1 = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
        let mut st = KernelStats::new();
        fwd(&cfg, &d, &g, &mut y1, SkipMode::MaskLoop, &mut st);
        let mut y2 = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
        let mut st2 = KernelStats::new();
        let mut views = y2.par_row_tiles_mut(plan.q / V);
        assert_eq!(views.len(), cfg.n * cfg.out_h() * (cfg.k / plan.q));
        for view in views.iter_mut().rev() {
            fwd_task(
                &cfg, &d, &g, view, SkipMode::MaskLoop, &plan, &geom, bk, &mut scratch, &mut st2,
            );
        }
        drop(views);
        assert_eq!(y1.data(), y2.data());
        assert_eq!(st.fma_vec, st2.fma_vec);
    }

    /// Reduced-geometry Miri gate: the view-based task decomposition (the
    /// slices `fwd_task` actually writes through) equals the whole-kernel
    /// run on a layer small enough for the interpreter, in all three skip
    /// modes — UB in the view plumbing or the FMA indexing fails here.
    #[test]
    fn miri_reduced_view_tasks_cover_whole() {
        let cfg = ConvConfig::square(1, 16, 16, 4, 3, 1);
        let (d, g) = sparse_setup(&cfg, 0.5, 11);
        let plan = super::plan_fwd(cfg.k, cfg.r);
        let geom = SweepGeom::fwd(&cfg);
        let bk = simd::dispatch();
        for mode in [SkipMode::Dense, SkipMode::PerLaneBranch, SkipMode::MaskLoop] {
            let mut y1 = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
            let mut st = KernelStats::new();
            fwd(&cfg, &d, &g, &mut y1, mode, &mut st);
            let mut y2 = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
            let mut st2 = KernelStats::new();
            let mut scratch = Scratch::new();
            for view in y2.par_row_tiles_mut(plan.q / V).iter_mut().rev() {
                fwd_task(&cfg, &d, &g, view, mode, &plan, &geom, bk, &mut scratch, &mut st2);
            }
            assert_eq!(y1.data(), y2.data(), "mode={mode:?}");
            assert_eq!(st.fma_vec, st2.fma_vec, "mode={mode:?}");
            assert_eq!(st.zero_checks, st2.zero_checks, "mode={mode:?}");
        }
    }
}
