//! Scalar 7-loop reference convolutions over plain NCHW/KCSR buffers.
//!
//! These are the correctness oracles: deliberately naïve, no tiling, no
//! vectorization, no sparsity exploitation. Every optimized kernel in this
//! crate is tested against them.

use super::ConvConfig;

/// Forward: `Y[i,k,y',x'] = Σ_{c,s,r} D[i,c,y'·P+s-pad_h, x'·O+r-pad_w] · G[k,c,s,r]`
/// over plain NCHW input (`d`), KCSR filters (`g`); returns NKH'W'.
pub fn conv_fwd(cfg: &ConvConfig, d: &[f32], g: &[f32]) -> Vec<f32> {
    let (oh, ow) = (cfg.out_h(), cfg.out_w());
    assert_eq!(d.len(), cfg.n * cfg.c * cfg.h * cfg.w);
    assert_eq!(g.len(), cfg.k * cfg.c * cfg.s * cfg.r);
    let mut y = vec![0.0f32; cfg.n * cfg.k * oh * ow];
    for i in 0..cfg.n {
        for k in 0..cfg.k {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc = 0.0f32;
                    for c in 0..cfg.c {
                        for s in 0..cfg.s {
                            let iy = (oy * cfg.stride_p + s) as isize - cfg.pad_h as isize;
                            if iy < 0 || iy >= cfg.h as isize {
                                continue;
                            }
                            for r in 0..cfg.r {
                                let ix = (ox * cfg.stride_o + r) as isize - cfg.pad_w as isize;
                                if ix < 0 || ix >= cfg.w as isize {
                                    continue;
                                }
                                acc += d[((i * cfg.c + c) * cfg.h + iy as usize) * cfg.w
                                    + ix as usize]
                                    * g[((k * cfg.c + c) * cfg.s + s) * cfg.r + r];
                            }
                        }
                    }
                    y[((i * cfg.k + k) * oh + oy) * ow + ox] = acc;
                }
            }
        }
    }
    y
}

/// Backward by input: `dD[i,c,y,x] = Σ_{k,s,r} dY[i,k,y',x'] · G[k,c,s,r]`
/// where `y'·P + s - pad_h = y`, `x'·O + r - pad_w = x`. Returns NCHW.
pub fn conv_bwi(cfg: &ConvConfig, dy: &[f32], g: &[f32]) -> Vec<f32> {
    let (oh, ow) = (cfg.out_h(), cfg.out_w());
    assert_eq!(dy.len(), cfg.n * cfg.k * oh * ow);
    assert_eq!(g.len(), cfg.k * cfg.c * cfg.s * cfg.r);
    let mut dd = vec![0.0f32; cfg.n * cfg.c * cfg.h * cfg.w];
    for i in 0..cfg.n {
        for k in 0..cfg.k {
            for oy in 0..oh {
                for ox in 0..ow {
                    let gout = dy[((i * cfg.k + k) * oh + oy) * ow + ox];
                    if gout == 0.0 {
                        continue; // pure arithmetic shortcut; result identical
                    }
                    for c in 0..cfg.c {
                        for s in 0..cfg.s {
                            let iy = (oy * cfg.stride_p + s) as isize - cfg.pad_h as isize;
                            if iy < 0 || iy >= cfg.h as isize {
                                continue;
                            }
                            for r in 0..cfg.r {
                                let ix = (ox * cfg.stride_o + r) as isize - cfg.pad_w as isize;
                                if ix < 0 || ix >= cfg.w as isize {
                                    continue;
                                }
                                dd[((i * cfg.c + c) * cfg.h + iy as usize) * cfg.w + ix as usize] +=
                                    gout * g[((k * cfg.c + c) * cfg.s + s) * cfg.r + r];
                            }
                        }
                    }
                }
            }
        }
    }
    dd
}

/// Backward by weights: `dG[k,c,s,r] = Σ_{i,y',x'} D[i,c,y'·P+s-pad_h, x'·O+r-pad_w] · dY[i,k,y',x']`.
/// Returns KCSR.
pub fn conv_bww(cfg: &ConvConfig, d: &[f32], dy: &[f32]) -> Vec<f32> {
    let (oh, ow) = (cfg.out_h(), cfg.out_w());
    assert_eq!(d.len(), cfg.n * cfg.c * cfg.h * cfg.w);
    assert_eq!(dy.len(), cfg.n * cfg.k * oh * ow);
    let mut dg = vec![0.0f32; cfg.k * cfg.c * cfg.s * cfg.r];
    for k in 0..cfg.k {
        for c in 0..cfg.c {
            for s in 0..cfg.s {
                for r in 0..cfg.r {
                    let mut acc = 0.0f32;
                    for i in 0..cfg.n {
                        for oy in 0..oh {
                            let iy = (oy * cfg.stride_p + s) as isize - cfg.pad_h as isize;
                            if iy < 0 || iy >= cfg.h as isize {
                                continue;
                            }
                            for ox in 0..ow {
                                let ix = (ox * cfg.stride_o + r) as isize - cfg.pad_w as isize;
                                if ix < 0 || ix >= cfg.w as isize {
                                    continue;
                                }
                                acc += d[((i * cfg.c + c) * cfg.h + iy as usize) * cfg.w
                                    + ix as usize]
                                    * dy[((i * cfg.k + k) * oh + oy) * ow + ox];
                            }
                        }
                    }
                    dg[((k * cfg.c + c) * cfg.s + s) * cfg.r + r] = acc;
                }
            }
        }
    }
    dg
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::allclose;
    use crate::util::prng::Xorshift;

    fn rand_vec(rng: &mut Xorshift, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.range_f32(-1.0, 1.0)).collect()
    }

    /// Finite-difference check of BWI/BWW against FWD: the backward passes
    /// must be the true gradients of L = Σ dy ⊙ Y(D, G).
    #[test]
    fn gradients_match_finite_difference() {
        let cfg = ConvConfig::square(2, 16, 16, 5, 3, 1);
        let mut rng = Xorshift::new(42);
        let d = rand_vec(&mut rng, cfg.n * cfg.c * cfg.h * cfg.w);
        let g = rand_vec(&mut rng, cfg.k * cfg.c * cfg.s * cfg.r);
        let dy = rand_vec(&mut rng, cfg.n * cfg.k * cfg.out_h() * cfg.out_w());

        let loss = |d: &[f32], g: &[f32]| -> f64 {
            conv_fwd(&cfg, d, g)
                .iter()
                .zip(&dy)
                .map(|(y, w)| (*y as f64) * (*w as f64))
                .sum()
        };

        let dd = conv_bwi(&cfg, &dy, &g);
        let dg = conv_bww(&cfg, &d, &dy);
        let eps = 1e-3f32;

        // spot-check a handful of coordinates
        let mut rng2 = Xorshift::new(7);
        for _ in 0..10 {
            let idx = rng2.below(d.len());
            let mut dp = d.clone();
            dp[idx] += eps;
            let mut dm = d.clone();
            dm[idx] -= eps;
            let fd = (loss(&dp, &g) - loss(&dm, &g)) / (2.0 * eps as f64);
            assert!(
                (fd - dd[idx] as f64).abs() < 2e-2,
                "dD[{idx}]: fd={fd} analytic={}",
                dd[idx]
            );
        }
        for _ in 0..10 {
            let idx = rng2.below(g.len());
            let mut gp = g.clone();
            gp[idx] += eps;
            let mut gm = g.clone();
            gm[idx] -= eps;
            let fd = (loss(&d, &gp) - loss(&d, &gm)) / (2.0 * eps as f64);
            assert!(
                (fd - dg[idx] as f64).abs() < 2e-2,
                "dG[{idx}]: fd={fd} analytic={}",
                dg[idx]
            );
        }
    }

    #[test]
    fn identity_filter_passes_through() {
        // 1x1 conv with identity mapping (K=C, G = I per-channel)
        let cfg = ConvConfig::square(1, 16, 16, 4, 1, 1);
        let mut rng = Xorshift::new(1);
        let d = rand_vec(&mut rng, cfg.n * cfg.c * cfg.h * cfg.w);
        let mut g = vec![0.0f32; cfg.k * cfg.c];
        for k in 0..16 {
            g[k * 16 + k] = 1.0;
        }
        let y = conv_fwd(&cfg, &d, &g);
        assert!(allclose(&y, &d, 1e-6, 1e-7));
    }

    #[test]
    fn strided_output_dims() {
        let cfg = ConvConfig::square(1, 16, 16, 8, 3, 2);
        let d = vec![1.0f32; cfg.n * cfg.c * cfg.h * cfg.w];
        let g = vec![1.0f32; cfg.k * cfg.c * 9];
        let y = conv_fwd(&cfg, &d, &g);
        assert_eq!(y.len(), cfg.n * cfg.k * 4 * 4);
        // interior outputs see the full 3x3*C support: 9*16 = 144
        let oh = cfg.out_h();
        let ow = cfg.out_w();
        let interior = y[(0 * oh + 1) * ow + 1];
        assert_eq!(interior, 144.0);
    }

    #[test]
    fn padding_zeros_do_not_contribute() {
        // All-ones input/filters: corner output of 3x3 pad-1 sees 4 taps/channel.
        let cfg = ConvConfig::square(1, 16, 16, 4, 3, 1);
        let d = vec![1.0f32; cfg.n * cfg.c * cfg.h * cfg.w];
        let g = vec![1.0f32; cfg.k * cfg.c * 9];
        let y = conv_fwd(&cfg, &d, &g);
        assert_eq!(y[0], 4.0 * 16.0); // corner
        assert_eq!(y[cfg.out_w() + 1], 9.0 * 16.0); // interior
    }
}
