//! SparseTrain backward propagation by weights (Algorithms 4 + 5).
//!
//! BWW differs from FWD/BWI (§3.4):
//! * the zero-check vectorizes along the **minibatch** dimension N (the dG
//!   destination is minibatch-invariant, so all V lanes of a
//!   `D[i:i+V, c, x, y]` vector update the *same* dG vectors — no register
//!   spills). The input is therefore the N-tiled layout
//!   [`BatchTiledTensor`];
//! * each input vector is checked **once per row sweep** (Algorithm 5,
//!   line 7) — one [`Backend::nonzero_mask`] compare; a nonzero lane then
//!   issues the full `T = R·Q/V` FMAs ([`Backend::axpy_v`]) across all
//!   filter taps touching that column;
//! * the `T` dG accumulators are **register-resident for the whole row
//!   sweep** — no cyclic renaming; previous partial results are loaded and
//!   added once at the end of the sweep and stored right back (the sweep
//!   accumulator itself is per-worker [`Scratch`], so no allocation per
//!   sweep);
//! * either D or ∂L/∂Y can be the checked operand; the caller picks the
//!   sparser one (§5.3 uses the higher average sparsity of the two).

use super::regalloc::{plan_bww, RegPlan};
use super::simd::{self, Backend};
use super::{ConvConfig, KernelStats, Scratch, SkipMode};
use crate::tensor::{ActTensor, BatchTiledTensor, FilterTensor, FilterTileMut};
use crate::V;

/// Per-input-column taps: for column `ix`, the (r, ox) pairs with
/// `ox·O + r − pad_w = ix`.
pub fn bww_col_taps(cfg: &ConvConfig) -> Vec<Vec<(usize, usize)>> {
    let ow = cfg.out_w();
    (0..cfg.w)
        .map(|ix| {
            (0..cfg.r)
                .filter_map(|r| {
                    let t = ix as isize + cfg.pad_w as isize - r as isize;
                    if t < 0 || t % cfg.stride_o as isize != 0 {
                        return None;
                    }
                    let ox = (t / cfg.stride_o as isize) as usize;
                    (ox < ow).then_some((r, ox))
                })
                .collect()
        })
        .collect()
}

/// SparseTrain BWW: checks zeros in `d` (the N-tiled input). `dg` is
/// accumulated into (zero it for a fresh gradient). Uses the process-wide
/// dispatched [`Backend`] and a fresh [`Scratch`].
pub fn bww(
    cfg: &ConvConfig,
    d: &BatchTiledTensor,
    dy: &ActTensor,
    dg: &mut FilterTensor,
    mode: SkipMode,
    stats: &mut KernelStats,
) {
    bww_with(cfg, d, dy, dg, mode, simd::dispatch(), &mut Scratch::new(), stats);
}

/// [`bww`] with an explicit backend and reusable scratch — the zero-alloc
/// entry point the wallclock harness and the parity suite drive.
#[allow(clippy::too_many_arguments)]
pub fn bww_with(
    cfg: &ConvConfig,
    d: &BatchTiledTensor,
    dy: &ActTensor,
    dg: &mut FilterTensor,
    mode: SkipMode,
    bk: Backend,
    scratch: &mut Scratch,
    stats: &mut KernelStats,
) {
    cfg.validate().expect("invalid conv config");
    assert!(cfg.n % V == 0, "BWW requires batch size multiple of V (§5.4)");
    let (oh, ow) = (cfg.out_h(), cfg.out_w());
    debug_assert_eq!((d.n, d.c, d.h, d.w), (cfg.n, cfg.c, cfg.h, cfg.w));
    debug_assert_eq!((dy.n, dy.c, dy.h, dy.w), (cfg.n, cfg.k, oh, ow));
    debug_assert_eq!((dg.k, dg.c, dg.s, dg.r), (cfg.k, cfg.c, cfg.s, cfg.r));

    let plan = plan_bww(cfg.k, cfg.r);
    let taps = bww_col_taps(cfg);

    // Iterate the same per-task (qb, c) tile views the parallel scheduler
    // distributes ([`FilterTensor::par_qc_tiles_mut`]), in the same order.
    for view in dg.par_qc_tiles_mut(plan.q / V).iter_mut() {
        bww_task(cfg, d, dy, view, &taps, mode, &plan, bk, scratch, stats);
    }
    stats.filter_bytes_per_sweep =
        stats.filter_bytes_per_sweep.max((cfg.r * plan.q * 4) as u64);
}

/// Per-task body for the parallel scheduler: one `(qb, c)` pair — a Q tile
/// of output channels × one input channel — swept over the whole minibatch
/// and every output row. The task accumulates only through its own
/// [`FilterTileMut`] view, the `dG[qb·Q .. (qb+1)·Q][c][*][*]` tile, so
/// the coordinator can run tasks in parallel without locks or atomics on
/// dG (§3.4's minibatch vectorization keeps each sweep's destination
/// minibatch-invariant) — and the borrow checker proves the tiles disjoint.
/// `plan` is the driver's [`plan_bww`] result, hoisted out of the per-sweep
/// hot path.
///
/// The task's `(nb, oy, s)` iteration order matches the serial [`bww`], so
/// the parallel result is bit-identical to the serial kernel.
#[allow(clippy::too_many_arguments)]
pub fn bww_task(
    cfg: &ConvConfig,
    d: &BatchTiledTensor,
    dy: &ActTensor,
    view: &mut FilterTileMut<'_>,
    taps: &[Vec<(usize, usize)>],
    mode: SkipMode,
    plan: &RegPlan,
    bk: Backend,
    scratch: &mut Scratch,
    stats: &mut KernelStats,
) {
    let oh = cfg.out_h();
    for nb in 0..cfg.n / V {
        for oy in 0..oh {
            for s in 0..cfg.s {
                let iy = oy as isize * cfg.stride_p as isize + s as isize - cfg.pad_h as isize;
                if iy < 0 || iy >= cfg.h as isize {
                    continue;
                }
                bww_sweep(
                    cfg, d, dy, view, nb, oy, iy as usize, s, taps, mode, plan, bk, scratch, stats,
                );
            }
        }
    }
}

/// One BWW row sweep: fixed (minibatch tile, output row, s-tap, Q tile,
/// input channel); accumulators cleared at entry, folded into the task's
/// dG tile view at exit. Scans *input columns*, one zero-check each
/// (Algorithm 5, line 7).
#[allow(clippy::too_many_arguments)]
pub fn bww_sweep(
    cfg: &ConvConfig,
    d: &BatchTiledTensor,
    dy: &ActTensor,
    view: &mut FilterTileMut<'_>,
    nb: usize,
    oy: usize,
    iy: usize,
    s: usize,
    taps: &[Vec<(usize, usize)>],
    mode: SkipMode,
    plan: &RegPlan,
    bk: Backend,
    scratch: &mut Scratch,
    stats: &mut KernelStats,
) {
    debug_assert_eq!(*plan, plan_bww(cfg.k, cfg.r), "plan must come from the driver's plan_bww");
    let qv = plan.q / V;
    debug_assert_eq!(view.tiles(), qv, "view tiling must match the register plan");
    let (qb, c) = (view.qb, view.c);

    // Register-resident accumulators: R × Q/V vectors, cleared at entry
    // (reused scratch — the old per-sweep vec![] allocation is gone).
    let acc = scratch.acc(cfg.r * qv * V);
    stats.sweeps += 1;

    for ix in 0..cfg.w {
        let tap = &taps[ix];
        if tap.is_empty() {
            continue;
        }
        let dvec = d.vec_arr(nb, c, iy, ix);
        stats.loads_in += 1;
        let mask = bk.nonzero_mask(dvec);
        let nonzeros = mask.count_ones() as usize;
        stats.record_check(nonzeros);
        let t_here = (tap.len() * qv) as u64;
        stats.fma_vec += nonzeros as u64 * t_here;
        stats.fma_vec_skipped += (V - nonzeros) as u64 * t_here;
        // the ∂L/∂Y operand comes from memory and is skipped along with the
        // FMA (§5.2's BWW high-sparsity advantage)

        match mode {
            SkipMode::Dense => {
                for nv in 0..V {
                    fma_lane(dy, acc, dvec[nv], nb * V + nv, qb, qv, oy, tap, bk);
                }
                stats.fma_vec += (V - nonzeros) as u64 * t_here;
                stats.fma_vec_skipped -= (V - nonzeros) as u64 * t_here;
            }
            SkipMode::PerLaneBranch => {
                for nv in 0..V {
                    if mask & (1 << nv) != 0 {
                        fma_lane(dy, acc, dvec[nv], nb * V + nv, qb, qv, oy, tap, bk);
                    }
                }
                stats.int_ops += V as u64;
            }
            SkipMode::MaskLoop => {
                let mut m = mask;
                while m != 0 {
                    let nv = m.trailing_zeros() as usize;
                    fma_lane(dy, acc, dvec[nv], nb * V + nv, qb, qv, oy, tap, bk);
                    m &= m - 1;
                }
                stats.int_ops += 2 + 8 * nonzeros as u64;
            }
        }
    }

    // Fold into dG: load previous partials, add, store back (§3.4 —
    // filter-gradient elements touched only twice, at sweep end). Scale
    // 1.0 makes the fused axpy round once on the sum — bit-equal to the
    // plain add it replaces.
    for r in 0..cfg.r {
        for j in 0..qv {
            let a = &acc[(r * qv + j) * V..(r * qv + j) * V + V];
            let gv = view.vec_mut(j, s, r);
            bk.axpy_v(gv, 1.0, a);
        }
    }
    stats.loads_out += (cfg.r * qv) as u64;
    stats.stores_out += (cfg.r * qv) as u64;
}

/// All FMAs for one nonzero input lane `i`: broadcast D element × the
/// ∂L/∂Y K-vectors (memory operands) for every tap touching this column,
/// through [`Backend::axpy_v`].
#[inline(always)]
#[allow(clippy::too_many_arguments)]
fn fma_lane(
    dy: &ActTensor,
    acc: &mut [f32],
    dval: f32,
    i: usize,
    qb: usize,
    qv: usize,
    oy: usize,
    taps: &[(usize, usize)],
    bk: Backend,
) {
    // Strength-reduced ∂L/∂Y indexing: for fixed (i, oy) the offset is
    // kb·kb_stride + ox·V + base (see sparse_fwd::fma_lane).
    let dyd = dy.data();
    let kb_stride = dy.h * dy.w * V;
    let row_base = (i * dy.c_blocks() * dy.h + oy) * dy.w * V;
    for &(r, ox) in taps {
        for j in 0..qv {
            let kb = qb * qv + j;
            let o = row_base + kb * kb_stride + ox * V;
            let dyvec = &dyd[o..o + V];
            let a = &mut acc[(r * qv + j) * V..(r * qv + j) * V + V];
            bk.axpy_v(a, dval, dyvec);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::reference;
    use super::*;
    use crate::tensor::allclose;
    use crate::util::prng::Xorshift;

    fn setup(
        cfg: &ConvConfig,
        d_sparsity: f64,
        seed: u64,
    ) -> (ActTensor, BatchTiledTensor, ActTensor) {
        let mut rng = Xorshift::new(seed);
        let mut dsrc = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
        dsrc.fill_relu_sparse(&mut rng, d_sparsity);
        let d = BatchTiledTensor::from_act(&dsrc);
        let mut dy = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
        dy.fill_uniform(&mut rng, -1.0, 1.0);
        (dsrc, d, dy)
    }

    fn run_and_check(cfg: &ConvConfig, sparsity: f64, mode: SkipMode) -> KernelStats {
        let (dsrc, d, dy) = setup(cfg, sparsity, 404);
        let mut dg = FilterTensor::zeros(cfg.k, cfg.c, cfg.s, cfg.r);
        let mut st = KernelStats::new();
        bww(cfg, &d, &dy, &mut dg, mode, &mut st);
        let dgref = reference::conv_bww(cfg, &dsrc.to_nchw(), &dy.to_nchw());
        assert!(allclose(&dg.to_kcsr(), &dgref, 1e-3, 1e-4), "mode={mode:?}");
        st
    }

    #[test]
    #[cfg_attr(miri, ignore = "too slow under miri; miri_* tests cover the reduced set")]
    fn matches_reference_all_modes() {
        let cfg = ConvConfig::square(16, 32, 32, 6, 3, 1);
        for mode in [SkipMode::Dense, SkipMode::PerLaneBranch, SkipMode::MaskLoop] {
            run_and_check(&cfg, 0.5, mode);
        }
    }

    #[test]
    #[cfg_attr(miri, ignore = "too slow under miri; miri_* tests cover the reduced set")]
    fn matches_reference_strided() {
        let cfg = ConvConfig::square(16, 32, 32, 8, 3, 2);
        run_and_check(&cfg, 0.5, SkipMode::MaskLoop);
    }

    #[test]
    #[cfg_attr(miri, ignore = "too slow under miri; miri_* tests cover the reduced set")]
    fn matches_reference_1x1() {
        let cfg = ConvConfig::square(16, 32, 64, 5, 1, 1);
        run_and_check(&cfg, 0.6, SkipMode::MaskLoop);
    }

    #[test]
    #[cfg_attr(miri, ignore = "too slow under miri; miri_* tests cover the reduced set")]
    fn skip_fraction_tracks_sparsity() {
        let cfg = ConvConfig::square(16, 32, 64, 8, 3, 1);
        for target in [0.3, 0.8] {
            let st = run_and_check(&cfg, target, SkipMode::MaskLoop);
            assert!(
                (st.skip_fraction() - target).abs() < 0.05,
                "target={target} got={}",
                st.skip_fraction()
            );
        }
    }

    #[test]
    #[cfg_attr(miri, ignore = "too slow under miri; miri_* tests cover the reduced set")]
    fn one_check_per_input_column() {
        // Algorithm 5: the mask is computed once per input vector per
        // sweep — not once per filter tap.
        let cfg = ConvConfig::square(16, 16, 16, 6, 3, 1);
        let st = run_and_check(&cfg, 0.5, SkipMode::MaskLoop);
        // every input column has ≥1 tap for 3x3 pad-1 s1, so checks ==
        // sweeps × W
        assert_eq!(st.zero_checks, st.sweeps * cfg.w as u64);
    }

    #[test]
    #[cfg_attr(miri, ignore = "too slow under miri; miri_* tests cover the reduced set")]
    fn accumulates_into_existing_dg() {
        // Two half-batches accumulated == one full batch (gradient
        // accumulation invariant the trainer relies on).
        let cfg_full = ConvConfig::square(32, 16, 16, 5, 3, 1);
        let cfg_half = ConvConfig::square(16, 16, 16, 5, 3, 1);
        let (dsrc, d, dy) = setup(&cfg_full, 0.5, 15);
        let mut dg_full = FilterTensor::zeros(16, 16, 3, 3);
        let mut st = KernelStats::new();
        bww(&cfg_full, &d, &dy, &mut dg_full, SkipMode::MaskLoop, &mut st);

        let nchw = dsrc.to_nchw();
        let dy_nchw = dy.to_nchw();
        let img = 16 * 5 * 5;
        let mut dg_acc = FilterTensor::zeros(16, 16, 3, 3);
        for half in 0..2 {
            let d_half =
                ActTensor::from_nchw(16, 16, 5, 5, &nchw[half * 16 * img..(half + 1) * 16 * img]);
            let dy_half =
                ActTensor::from_nchw(16, 16, 5, 5, &dy_nchw[half * 16 * img..(half + 1) * 16 * img]);
            let mut st2 = KernelStats::new();
            bww(
                &cfg_half,
                &BatchTiledTensor::from_act(&d_half),
                &dy_half,
                &mut dg_acc,
                SkipMode::MaskLoop,
                &mut st2,
            );
        }
        assert!(allclose(dg_full.data(), dg_acc.data(), 1e-3, 1e-4));
    }

    #[test]
    #[cfg_attr(miri, ignore = "too slow under miri; miri_* tests cover the reduced set")]
    fn dg_touched_twice_per_sweep_only() {
        // loads_out == stores_out == R·Q/V per sweep
        let cfg = ConvConfig::square(16, 16, 256, 6, 3, 1);
        let st = run_and_check(&cfg, 0.5, SkipMode::MaskLoop);
        let plan = plan_bww(cfg.k, cfg.r);
        assert_eq!(st.loads_out, st.sweeps * (cfg.r * plan.q / V) as u64);
        assert_eq!(st.stores_out, st.loads_out);
    }

    /// Reduced-geometry Miri gate: the view-based `(qb, c)` task
    /// decomposition (the dG tiles `bww_task` accumulates into) equals the
    /// whole-kernel run on a layer small enough for the interpreter.
    #[test]
    fn miri_reduced_view_tasks_cover_whole() {
        let cfg = ConvConfig::square(16, 16, 16, 3, 3, 1);
        let (_, d, dy) = setup(&cfg, 0.5, 29);
        let plan = plan_bww(cfg.k, cfg.r);
        let taps = bww_col_taps(&cfg);
        let bk = simd::dispatch();
        let mut dg1 = FilterTensor::zeros(cfg.k, cfg.c, cfg.s, cfg.r);
        let mut st = KernelStats::new();
        bww(&cfg, &d, &dy, &mut dg1, SkipMode::MaskLoop, &mut st);
        let mut dg2 = FilterTensor::zeros(cfg.k, cfg.c, cfg.s, cfg.r);
        let mut st2 = KernelStats::new();
        let mut scratch = Scratch::new();
        let mode = SkipMode::MaskLoop;
        for view in dg2.par_qc_tiles_mut(plan.q / V).iter_mut().rev() {
            bww_task(&cfg, &d, &dy, view, &taps, mode, &plan, bk, &mut scratch, &mut st2);
        }
        assert_eq!(dg1.data(), dg2.data());
        assert_eq!(st.fma_vec, st2.fma_vec);
        assert_eq!(st.zero_checks, st2.zero_checks);
    }
}
