//! Explicit-SIMD backend for the three hot kernel primitives.
//!
//! The paper's speedup story rests on two machine facts (§3.2.1, §3.2.4):
//! one vector compare produces a lane mask (`vcmpps` + `kmov`/`movmsk`),
//! and each surviving lane costs exactly one V-wide FMA per `(tap, Q-tile)`
//! pair (`vfmadd231ps` with a memory operand). The scalar `for l in 0..V`
//! loops the kernels used to carry merely *hoped* the autovectorizer would
//! emit those instructions; this module makes them explicit and lets a
//! [`Backend`] value — resolved **once per process** with
//! `is_x86_feature_detected!` — carry the chosen implementation through the
//! kernels as plain function pointers.
//!
//! | primitive | semantics | x86-64 | AArch64 |
//! |---|---|---|---|
//! | [`Backend::nonzero_mask`] | bit `l` set iff `v[l] != 0.0` | `vcmpps(NEQ_UQ)` + mask extract | `vceqzq`+`vmvnq`+bit-select |
//! | [`Backend::axpy_v`] | `acc[l] ← fma(g[l], s, acc[l])` | `vfmadd` (AVX-512F / AVX2+FMA) | `vfmaq_n_f32` |
//! | [`Backend::copy_v`] | `dst ← src` (one V-vector) | vector load + store | vector load + store |
//!
//! **Dispatch order** (first available wins): AVX-512F (only when the
//! `avx512` cargo feature is on — the AVX-512 intrinsics need rustc ≥ 1.89),
//! AVX2+FMA, NEON (unconditional on AArch64), scalar. The scalar path is
//! the *mandatory* backend under Miri (`cfg!(miri)` short-circuits
//! detection) and on unknown targets, and can be forced anywhere with
//! `SPARSETRAIN_BACKEND=scalar` — that is the reference implementation the
//! parity suite compares every SIMD path against.
//!
//! **Bit-exactness.** All backends implement the *same* arithmetic: a fused
//! multiply-add with a single rounding (`f32::mul_add` in the scalar path,
//! hardware FMA in the vector paths) and an IEEE-754 `!= 0.0` compare
//! (`-0.0` is zero, NaN is nonzero, matching the scalar `v != 0.0`). The
//! SIMD-vs-scalar parity tests therefore assert **bit-identical** outputs,
//! not approximate ones, and the serial/parallel bit-exactness contract of
//! the scheduler is unchanged.

use crate::V;
use std::sync::OnceLock;

/// Which instruction set backs the primitives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// Portable scalar loops (`f32::mul_add`): the Miri/reference path.
    Scalar,
    /// 2× 256-bit ops per primitive (`vfmadd231ps ymm`, `vcmpps`+`vmovmskps`).
    Avx2,
    /// 1× 512-bit op per primitive (`vfmadd231ps zmm`, `vcmpps k`).
    Avx512,
    /// 4× 128-bit ops per primitive (`fmla.4s`, `fcmeq`+bit-select).
    Neon,
}

type MaskFn = fn(&[f32; V]) -> u32;
type AxpyFn = fn(&mut [f32; V], f32, &[f32; V]);
type CopyFn = fn(&mut [f32; V], &[f32; V]);

/// A resolved primitive set. `Copy` so kernels thread it by value; the
/// function pointers are bound once at detection time, so the hot loops
/// pay an indirect call (predicted perfectly — the target never changes)
/// instead of a per-call feature check.
#[derive(Debug, Clone, Copy)]
pub struct Backend {
    kind: BackendKind,
    mask_fn: MaskFn,
    axpy_fn: AxpyFn,
    copy_fn: CopyFn,
}

#[inline(always)]
fn arr(v: &[f32]) -> &[f32; V] {
    v.try_into().expect("primitive operand must be exactly V lanes")
}

#[inline(always)]
fn arr_mut(v: &mut [f32]) -> &mut [f32; V] {
    v.try_into().expect("primitive operand must be exactly V lanes")
}

impl Backend {
    /// Bit `l` of the result is set iff `v[l] != 0.0` — the vectorized
    /// zero-check of §3.2.1. `-0.0` counts as zero and NaN as nonzero,
    /// exactly like the scalar compare.
    #[inline(always)]
    pub fn nonzero_mask(&self, v: &[f32; V]) -> u32 {
        (self.mask_fn)(v)
    }

    /// `acc[l] += scale * g[l]` as one fused multiply-add per lane (one
    /// V-wide FMA on vector backends). `acc` and `g` must be exactly `V`
    /// lanes.
    #[inline(always)]
    pub fn axpy_v(&self, acc: &mut [f32], scale: f32, g: &[f32]) {
        (self.axpy_fn)(arr_mut(acc), scale, arr(g))
    }

    /// Copy one V-vector (`dst ← src`). Both must be exactly `V` lanes.
    /// For *single*-vector moves; the kernels deliberately keep
    /// `copy_from_slice` (one memcpy) for whole-row loads/stores, where a
    /// per-vector indirect call would only add overhead.
    #[inline(always)]
    pub fn copy_v(&self, dst: &mut [f32], src: &[f32]) {
        (self.copy_fn)(arr_mut(dst), arr(src))
    }

    pub fn kind(&self) -> BackendKind {
        self.kind
    }

    /// Stable lowercase name ("scalar", "avx2", "avx512", "neon") — the
    /// value recorded in `BENCH_kernels.json` and accepted by the
    /// `SPARSETRAIN_BACKEND` override.
    pub fn name(&self) -> &'static str {
        match self.kind {
            BackendKind::Scalar => "scalar",
            BackendKind::Avx2 => "avx2",
            BackendKind::Avx512 => "avx512",
            BackendKind::Neon => "neon",
        }
    }

    /// The portable scalar backend — always available, the mandatory path
    /// under Miri and the reference for the parity suite.
    pub fn scalar() -> Backend {
        Backend {
            kind: BackendKind::Scalar,
            mask_fn: scalar::nonzero_mask,
            axpy_fn: scalar::axpy,
            copy_fn: scalar::copy,
        }
    }

    /// Detect the best backend for this process (ignoring the env
    /// override): AVX-512F → AVX2+FMA → NEON → scalar. Under Miri this is
    /// always scalar — the interpreter must run the portable path.
    #[allow(unreachable_code)]
    pub fn detect() -> Backend {
        if cfg!(miri) {
            return Backend::scalar();
        }
        #[cfg(target_arch = "x86_64")]
        {
            #[cfg(feature = "avx512")]
            if is_x86_feature_detected!("avx512f") {
                return x86::avx512_backend();
            }
            if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
                return x86::avx2_backend();
            }
        }
        #[cfg(target_arch = "aarch64")]
        {
            return neon::backend();
        }
        Backend::scalar()
    }

    /// Look up a backend by name, returning `None` when it is unknown or
    /// not available on this machine/build (e.g. "avx512" without the
    /// `avx512` cargo feature or on a non-AVX-512 CPU).
    pub fn by_name(name: &str) -> Option<Backend> {
        match name {
            "scalar" => Some(Backend::scalar()),
            #[cfg(all(target_arch = "x86_64", not(miri)))]
            "avx2" => (is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma"))
                .then(x86::avx2_backend),
            #[cfg(all(target_arch = "x86_64", not(miri), feature = "avx512"))]
            "avx512" => is_x86_feature_detected!("avx512f").then(x86::avx512_backend),
            #[cfg(all(target_arch = "aarch64", not(miri)))]
            "neon" => Some(neon::backend()),
            _ => None,
        }
    }
}

/// The process-wide dispatched backend, resolved exactly once: the
/// `SPARSETRAIN_BACKEND` env var (scalar/avx2/avx512/neon) if set,
/// otherwise [`Backend::detect`]. An explicit override that cannot be
/// honored (unknown name, or a backend unavailable on this machine/build)
/// **panics** — silently running a different backend than the one forced
/// would let e.g. the forced-scalar CI leg pass while testing AVX2.
pub fn dispatch() -> Backend {
    static CHOSEN: OnceLock<Backend> = OnceLock::new();
    *CHOSEN.get_or_init(|| match std::env::var("SPARSETRAIN_BACKEND") {
        Ok(name) => Backend::by_name(&name).unwrap_or_else(|| {
            panic!(
                "SPARSETRAIN_BACKEND={name} is unknown or unavailable on this \
                 machine/build (valid: scalar, avx2, avx512 [needs the avx512 \
                 cargo feature], neon); unset it to use auto-detection"
            )
        }),
        Err(_) => Backend::detect(),
    })
}

/// Portable reference implementation. `mul_add` is a *fused* multiply-add
/// (one rounding), so the vector backends' `vfmadd`/`fmla` produce
/// bit-identical results. Tradeoff: on targets without hardware FMA (e.g.
/// pre-Haswell x86-64) `mul_add` lowers to a libm `fmaf` call per lane —
/// slower than the autovectorized mul-then-add it replaced. That is the
/// price of cross-backend bit-identity, and it only affects the fallback
/// tier: every dispatched vector backend has hardware FMA by construction.
mod scalar {
    use crate::V;

    pub(super) fn nonzero_mask(v: &[f32; V]) -> u32 {
        let mut m = 0u32;
        for (l, &x) in v.iter().enumerate() {
            if x != 0.0 {
                m |= 1 << l;
            }
        }
        m
    }

    pub(super) fn axpy(acc: &mut [f32; V], scale: f32, g: &[f32; V]) {
        for l in 0..V {
            acc[l] = g[l].mul_add(scale, acc[l]);
        }
    }

    pub(super) fn copy(dst: &mut [f32; V], src: &[f32; V]) {
        *dst = *src;
    }
}

/// x86-64 implementations. The `#[target_feature]` inner functions are
/// `unsafe fn`s; the safe entry wrappers are only ever installed into a
/// [`Backend`] after `is_x86_feature_detected!` confirmed the features, so
/// the `unsafe` obligation (ISA availability) is discharged at
/// construction time.
#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::{Backend, BackendKind};
    use crate::V;
    use core::arch::x86_64::*;

    pub(super) fn avx2_backend() -> Backend {
        Backend {
            kind: BackendKind::Avx2,
            mask_fn: mask_avx2_entry,
            axpy_fn: axpy_avx2_entry,
            copy_fn: copy_avx2_entry,
        }
    }

    fn mask_avx2_entry(v: &[f32; V]) -> u32 {
        // SAFETY: installed only after avx2+fma detection.
        unsafe { mask_avx2(v) }
    }
    fn axpy_avx2_entry(acc: &mut [f32; V], s: f32, g: &[f32; V]) {
        // SAFETY: installed only after avx2+fma detection.
        unsafe { axpy_avx2(acc, s, g) }
    }
    fn copy_avx2_entry(dst: &mut [f32; V], src: &[f32; V]) {
        // SAFETY: installed only after avx2+fma detection.
        unsafe { copy_avx2(dst, src) }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn mask_avx2(v: &[f32; V]) -> u32 {
        let zero = _mm256_setzero_ps();
        let lo = _mm256_loadu_ps(v.as_ptr());
        let hi = _mm256_loadu_ps(v.as_ptr().add(8));
        // NEQ_UQ: unordered quiet not-equal — NaN != 0.0 is true, -0.0
        // compares equal to 0.0, matching the scalar `x != 0.0`.
        let mlo = _mm256_movemask_ps(_mm256_cmp_ps::<_CMP_NEQ_UQ>(lo, zero)) as u32;
        let mhi = _mm256_movemask_ps(_mm256_cmp_ps::<_CMP_NEQ_UQ>(hi, zero)) as u32;
        mlo | (mhi << 8)
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn axpy_avx2(acc: &mut [f32; V], s: f32, g: &[f32; V]) {
        let sv = _mm256_set1_ps(s);
        let a0 = _mm256_loadu_ps(acc.as_ptr());
        let g0 = _mm256_loadu_ps(g.as_ptr());
        _mm256_storeu_ps(acc.as_mut_ptr(), _mm256_fmadd_ps(g0, sv, a0));
        let a1 = _mm256_loadu_ps(acc.as_ptr().add(8));
        let g1 = _mm256_loadu_ps(g.as_ptr().add(8));
        _mm256_storeu_ps(acc.as_mut_ptr().add(8), _mm256_fmadd_ps(g1, sv, a1));
    }

    #[target_feature(enable = "avx2")]
    unsafe fn copy_avx2(dst: &mut [f32; V], src: &[f32; V]) {
        _mm256_storeu_ps(dst.as_mut_ptr(), _mm256_loadu_ps(src.as_ptr()));
        _mm256_storeu_ps(dst.as_mut_ptr().add(8), _mm256_loadu_ps(src.as_ptr().add(8)));
    }

    #[cfg(feature = "avx512")]
    pub(super) fn avx512_backend() -> Backend {
        Backend {
            kind: BackendKind::Avx512,
            mask_fn: mask_avx512_entry,
            axpy_fn: axpy_avx512_entry,
            copy_fn: copy_avx512_entry,
        }
    }

    #[cfg(feature = "avx512")]
    fn mask_avx512_entry(v: &[f32; V]) -> u32 {
        // SAFETY: installed only after avx512f detection.
        unsafe { mask_avx512(v) }
    }
    #[cfg(feature = "avx512")]
    fn axpy_avx512_entry(acc: &mut [f32; V], s: f32, g: &[f32; V]) {
        // SAFETY: installed only after avx512f detection.
        unsafe { axpy_avx512(acc, s, g) }
    }
    #[cfg(feature = "avx512")]
    fn copy_avx512_entry(dst: &mut [f32; V], src: &[f32; V]) {
        // SAFETY: installed only after avx512f detection.
        unsafe { copy_avx512(dst, src) }
    }

    /// One `vcmpps zmm, k` + `kmovw` — exactly the paper's zero-check.
    #[cfg(feature = "avx512")]
    #[target_feature(enable = "avx512f")]
    unsafe fn mask_avx512(v: &[f32; V]) -> u32 {
        let x = _mm512_loadu_ps(v.as_ptr());
        _mm512_cmp_ps_mask::<_CMP_NEQ_UQ>(x, _mm512_setzero_ps()) as u32
    }

    /// One `vfmadd231ps zmm` — the paper's per-lane FMA group body.
    #[cfg(feature = "avx512")]
    #[target_feature(enable = "avx512f")]
    unsafe fn axpy_avx512(acc: &mut [f32; V], s: f32, g: &[f32; V]) {
        let a = _mm512_loadu_ps(acc.as_ptr());
        let gv = _mm512_loadu_ps(g.as_ptr());
        _mm512_storeu_ps(acc.as_mut_ptr(), _mm512_fmadd_ps(gv, _mm512_set1_ps(s), a));
    }

    #[cfg(feature = "avx512")]
    #[target_feature(enable = "avx512f")]
    unsafe fn copy_avx512(dst: &mut [f32; V], src: &[f32; V]) {
        _mm512_storeu_ps(dst.as_mut_ptr(), _mm512_loadu_ps(src.as_ptr()));
    }
}

/// AArch64 NEON implementations. NEON is architecturally mandatory on
/// AArch64, so the entry wrappers are unconditionally sound there.
#[cfg(target_arch = "aarch64")]
mod neon {
    use super::{Backend, BackendKind};
    use crate::V;
    use core::arch::aarch64::*;

    pub(super) fn backend() -> Backend {
        Backend {
            kind: BackendKind::Neon,
            mask_fn: mask_neon_entry,
            axpy_fn: axpy_neon_entry,
            copy_fn: copy_neon_entry,
        }
    }

    fn mask_neon_entry(v: &[f32; V]) -> u32 {
        // SAFETY: NEON is mandatory on aarch64.
        unsafe { mask_neon(v) }
    }
    fn axpy_neon_entry(acc: &mut [f32; V], s: f32, g: &[f32; V]) {
        // SAFETY: NEON is mandatory on aarch64.
        unsafe { axpy_neon(acc, s, g) }
    }
    fn copy_neon_entry(dst: &mut [f32; V], src: &[f32; V]) {
        // SAFETY: NEON is mandatory on aarch64.
        unsafe { copy_neon(dst, src) }
    }

    #[target_feature(enable = "neon")]
    unsafe fn mask_neon(v: &[f32; V]) -> u32 {
        // No movemask on NEON: select a per-lane bit via AND with
        // (1, 2, 4, 8) and reduce with a horizontal add per quad.
        let lane_bits: [u32; 4] = [1, 2, 4, 8];
        let bits = vld1q_u32(lane_bits.as_ptr());
        let mut m = 0u32;
        for q in 0..4 {
            let x = vld1q_f32(v.as_ptr().add(q * 4));
            // vceqzq: lanes equal to ±0.0 (NaN lanes false) — invert for
            // the nonzero mask, matching the scalar `x != 0.0`.
            let nz = vmvnq_u32(vceqzq_f32(x));
            m |= vaddvq_u32(vandq_u32(nz, bits)) << (q * 4);
        }
        m
    }

    #[target_feature(enable = "neon")]
    unsafe fn axpy_neon(acc: &mut [f32; V], s: f32, g: &[f32; V]) {
        for q in 0..4 {
            let a = vld1q_f32(acc.as_ptr().add(q * 4));
            let gv = vld1q_f32(g.as_ptr().add(q * 4));
            vst1q_f32(acc.as_mut_ptr().add(q * 4), vfmaq_n_f32(a, gv, s));
        }
    }

    #[target_feature(enable = "neon")]
    unsafe fn copy_neon(dst: &mut [f32; V], src: &[f32; V]) {
        for q in 0..4 {
            vst1q_f32(dst.as_mut_ptr().add(q * 4), vld1q_f32(src.as_ptr().add(q * 4)));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Xorshift;

    fn random_vec(rng: &mut Xorshift, sparsity: f64) -> [f32; V] {
        let mut v = [0.0f32; V];
        for x in v.iter_mut() {
            if rng.next_f64() >= sparsity {
                *x = (rng.next_f64() * 2.0 - 1.0) as f32;
            }
        }
        v
    }

    #[test]
    fn scalar_mask_semantics() {
        let bk = Backend::scalar();
        assert_eq!(bk.nonzero_mask(&[0.0; V]), 0);
        assert_eq!(bk.nonzero_mask(&[1.0; V]), 0xFFFF);
        let mut v = [0.0f32; V];
        v[0] = 1.0;
        v[3] = -2.5;
        v[15] = 1e-30;
        assert_eq!(bk.nonzero_mask(&v), 1 | (1 << 3) | (1 << 15));
        // -0.0 is zero; NaN is nonzero (matches the scalar `x != 0.0`)
        v = [0.0; V];
        v[1] = -0.0;
        v[2] = f32::NAN;
        assert_eq!(bk.nonzero_mask(&v), 1 << 2);
    }

    #[test]
    fn scalar_axpy_is_fused() {
        let bk = Backend::scalar();
        let mut acc = [1.0f32; V];
        let g: [f32; V] = core::array::from_fn(|l| l as f32);
        bk.axpy_v(&mut acc, 0.5, &g);
        for (l, &a) in acc.iter().enumerate() {
            assert_eq!(a, (l as f32).mul_add(0.5, 1.0));
        }
    }

    #[test]
    fn copy_v_copies() {
        let bk = dispatch();
        let src: [f32; V] = core::array::from_fn(|l| l as f32 - 7.5);
        let mut dst = [0.0f32; V];
        bk.copy_v(&mut dst, &src);
        assert_eq!(dst, src);
    }

    /// The dispatched backend must be bit-identical to scalar on both
    /// primitives across random vectors — the unit-level half of the
    /// SIMD-vs-scalar parity contract (the kernel-level half lives in
    /// `rust/tests/backend_parity.rs`). Under Miri the dispatched backend
    /// *is* scalar, which also pins the mandatory-scalar rule.
    #[test]
    fn dispatched_backend_matches_scalar_bitwise() {
        let bk = dispatch();
        let sc = Backend::scalar();
        if cfg!(miri) {
            assert_eq!(bk.kind(), BackendKind::Scalar, "Miri must run the scalar path");
        }
        let mut rng = Xorshift::new(0x51D);
        for case in 0..200 {
            let sparsity = [0.0, 0.3, 0.6, 0.9][case % 4];
            let v = random_vec(&mut rng, sparsity);
            assert_eq!(bk.nonzero_mask(&v), sc.nonzero_mask(&v), "mask case {case}");
            let g = random_vec(&mut rng, 0.0);
            let scale = (rng.next_f64() * 4.0 - 2.0) as f32;
            let mut a1 = random_vec(&mut rng, 0.0);
            let mut a2 = a1;
            bk.axpy_v(&mut a1, scale, &g);
            sc.axpy_v(&mut a2, scale, &g);
            assert_eq!(a1, a2, "axpy case {case} (backend {})", bk.name());
        }
    }

    #[test]
    fn by_name_roundtrip_and_unknown() {
        assert_eq!(Backend::by_name("scalar").unwrap().kind(), BackendKind::Scalar);
        assert!(Backend::by_name("nope").is_none());
        let bk = dispatch();
        // the dispatched backend's own name must resolve back to it
        assert_eq!(Backend::by_name(bk.name()).map(|b| b.kind()), Some(bk.kind()));
    }
}
