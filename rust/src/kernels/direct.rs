//! Dense direct convolution — the `direct` baseline (MKL-DNN-style).
//!
//! Same loop order, tiling and data layout as the SparseTrain kernels
//! (input-row sweeps, Q-tiled output channels, filter-vector FMA operands)
//! but with **no** zero checking and **no** skipping: every lane of every
//! input vector is processed unconditionally. The paper's `direct` baseline
//! is a highly tuned dense kernel with the same blocking strategy
//! (Georganas et al. [11]); sharing the structure makes the 0 %-sparsity
//! comparison isolate exactly the cost of the sparsity machinery.

use super::regalloc::{plan_bww, plan_fwd};
use super::simd::{self, Backend};
use super::{ConvConfig, KernelStats, Scratch};
use crate::tensor::{ActTensor, BatchTiledTensor, FilterTensor};
use crate::V;

/// Precomputed sweep geometry: for each input column `x`, the list of
/// (filter tap r, output column x') pairs it touches. Shared by the dense
/// and sparse kernels so they perform identical index math; the drivers
/// compute it once and pass it into every task (hoisted out of the hot
/// path alongside the register plan).
pub struct SweepGeom {
    /// For each x: (r, x') pairs (length ≤ R).
    pub taps: Vec<Vec<(usize, usize)>>,
}

impl SweepGeom {
    /// Geometry of a forward row sweep: input column x feeds output x'
    /// where `x'·O + r - pad_w = x`.
    pub fn fwd(cfg: &ConvConfig) -> SweepGeom {
        let ow = cfg.out_w();
        let taps = (0..cfg.w)
            .map(|x| {
                (0..cfg.r)
                    .filter_map(|r| {
                        let t = x as isize + cfg.pad_w as isize - r as isize;
                        if t < 0 || t % cfg.stride_o as isize != 0 {
                            return None;
                        }
                        let xo = (t / cfg.stride_o as isize) as usize;
                        (xo < ow).then_some((r, xo))
                    })
                    .collect()
            })
            .collect();
        SweepGeom { taps }
    }

    /// Total (x, tap) pairs in a full row sweep.
    pub fn total_taps(&self) -> usize {
        self.taps.iter().map(Vec::len).sum()
    }
}

/// Dense direct forward convolution over the tiled layouts.
///
/// `y` must be zero-initialized (the kernel accumulates into it).
pub fn fwd(
    cfg: &ConvConfig,
    d: &ActTensor,
    g: &FilterTensor,
    y: &mut ActTensor,
    stats: &mut KernelStats,
) {
    fwd_with(cfg, d, g, y, simd::dispatch(), &mut Scratch::new(), stats);
}

/// [`fwd`] with an explicit backend and reusable scratch (zero-alloc
/// steady state for the wallclock harness).
pub fn fwd_with(
    cfg: &ConvConfig,
    d: &ActTensor,
    g: &FilterTensor,
    y: &mut ActTensor,
    bk: Backend,
    scratch: &mut Scratch,
    stats: &mut KernelStats,
) {
    cfg.validate().expect("invalid conv config");
    debug_assert_eq!((d.n, d.c, d.h, d.w), (cfg.n, cfg.c, cfg.h, cfg.w));
    debug_assert_eq!((g.k, g.c, g.s, g.r), (cfg.k, cfg.c, cfg.s, cfg.r));
    debug_assert_eq!((y.n, y.c, y.h, y.w), (cfg.n, cfg.k, cfg.out_h(), cfg.out_w()));

    let plan = plan_fwd(cfg.k, cfg.r);
    let qv = plan.q / V; // k-vectors per Q tile
    let geom = SweepGeom::fwd(cfg);
    let (oh, ow) = (cfg.out_h(), cfg.out_w());
    let cb_count = cfg.c / V;
    let kq_count = cfg.k / plan.q;

    // Task structure mirrors the SparseTrain kernel (same blocking per
    // Georganas et al. [11]): per (i, oy, qb) the output row stays in a
    // reused scratch accumulator across the (s, cb) sweeps (acc_uninit:
    // the per-task row load overwrites every element).
    let acc = scratch.acc_uninit(ow * qv * V);
    for i in 0..cfg.n {
        for oy in 0..oh {
            for qb in 0..kq_count {
                for j in 0..qv {
                    let kb = qb * qv + j;
                    acc[j * ow * V..(j + 1) * ow * V].copy_from_slice(y.row(i, kb, oy));
                }
                for s in 0..cfg.s {
                    let iy =
                        oy as isize * cfg.stride_p as isize + s as isize - cfg.pad_h as isize;
                    if iy < 0 || iy >= cfg.h as isize {
                        continue;
                    }
                    let iy = iy as usize;
                    for cb in 0..cb_count {
                        sweep_row_dense(cfg, d, g, acc, i, iy, s, qb, qv, cb, ow, &geom, bk);
                        account_sweep_dense(cfg, stats, &geom, qv, ow);
                    }
                }
                for j in 0..qv {
                    let kb = qb * qv + j;
                    y.row_mut(i, kb, oy).copy_from_slice(&acc[j * ow * V..(j + 1) * ow * V]);
                }
            }
        }
    }
    // Per-task output-row traffic (register-resident within a task).
    stats.loads_out += (cfg.n * oh * kq_count * ow * qv) as u64;
    stats.stores_out += (cfg.n * oh * kq_count * ow * qv) as u64;
    stats.filter_bytes_per_sweep =
        stats.filter_bytes_per_sweep.max((cfg.r * plan.q * V * 4) as u64);
}

/// One dense row sweep: all V lanes of every input vector processed,
/// scattered into the row accumulator through [`Backend::axpy_v`] — the
/// same V-wide FMA the sparse kernels issue, so the 0 %-sparsity
/// comparison isolates exactly the cost of the sparsity machinery.
#[allow(clippy::too_many_arguments)]
#[inline]
fn sweep_row_dense(
    cfg: &ConvConfig,
    d: &ActTensor,
    g: &FilterTensor,
    acc: &mut [f32],
    i: usize,
    iy: usize,
    s: usize,
    qb: usize,
    qv: usize,
    cb: usize,
    ow: usize,
    geom: &SweepGeom,
    bk: Backend,
) {
    for x in 0..cfg.w {
        let dvec = d.vec(i, cb, iy, x);
        let taps = &geom.taps[x];
        if taps.is_empty() {
            continue;
        }
        for cv in 0..V {
            let dval = dvec[cv];
            for j in 0..qv {
                let kb = qb * qv + j;
                let base = j * ow * V;
                for &(r, xo) in taps {
                    let gvec = g.vec(kb, cb, s, r, cv);
                    let a = &mut acc[base + xo * V..base + xo * V + V];
                    bk.axpy_v(a, dval, gvec);
                }
            }
        }
    }
}

/// Dense sweep accounting: all FMAs issued, no checks. Output-row
/// load/store is charged per *task* (i, oy, qb) — like SparseTrain, the
/// tuned dense kernel keeps the output row register-resident across the
/// (s, cb) accumulation (Georganas et al. [11]).
fn account_sweep_dense(cfg: &ConvConfig, stats: &mut KernelStats, geom: &SweepGeom, qv: usize, ow: usize) {
    let _ = (qv, ow);
    let taps = geom.total_taps() as u64;
    stats.fma_vec += taps * (V as u64) * qv as u64;
    stats.loads_in += cfg.w as u64;
    stats.sweeps += 1;
}

/// Dense direct backward-by-input: convolves ∂L/∂Y with transposed filters.
/// Implemented via the forward kernel over the BWI-equivalent configuration
/// for stride 1; for strided layers uses a scatter formulation.
pub fn bwi(
    cfg: &ConvConfig,
    dy: &ActTensor,
    g: &FilterTensor,
    dd: &mut ActTensor,
    stats: &mut KernelStats,
) {
    bwi_with(cfg, dy, g, dd, simd::dispatch(), stats);
}

/// [`bwi`] with an explicit backend.
pub fn bwi_with(
    cfg: &ConvConfig,
    dy: &ActTensor,
    g: &FilterTensor,
    dd: &mut ActTensor,
    bk: Backend,
    stats: &mut KernelStats,
) {
    cfg.validate().expect("invalid conv config");
    let (oh, ow) = (cfg.out_h(), cfg.out_w());
    debug_assert_eq!((dy.n, dy.c, dy.h, dy.w), (cfg.n, cfg.k, oh, ow));
    debug_assert_eq!((dd.n, dd.c, dd.h, dd.w), (cfg.n, cfg.c, cfg.h, cfg.w));

    // Scatter formulation mirroring the sparse BWI loop structure, dense.
    let plan = plan_fwd(cfg.c, cfg.r); // accumulators are C-vectors in BWI
    let qv = plan.q / V;
    let cq_count = cfg.c / plan.q;
    let kb_count = cfg.k / V;

    for i in 0..cfg.n {
        for oy in 0..oh {
            for s in 0..cfg.s {
                let iy = oy as isize * cfg.stride_p as isize + s as isize - cfg.pad_h as isize;
                if iy < 0 || iy >= cfg.h as isize {
                    continue;
                }
                let iy = iy as usize;
                for qb in 0..cq_count {
                    for kb in 0..kb_count {
                        for j in 0..qv {
                            let cb = qb * qv + j;
                            let ddoff = dd.vec_offset(i, cb, iy, 0);
                            for ox in 0..ow {
                                let dyvec = dy.vec(i, kb, oy, ox);
                                for kv in 0..V {
                                    let gval = dyvec[kv];
                                    for r in 0..cfg.r {
                                        let ix = ox as isize * cfg.stride_o as isize + r as isize
                                            - cfg.pad_w as isize;
                                        if ix < 0 || ix >= cfg.w as isize {
                                            continue;
                                        }
                                        // dD[i, cb-vec, iy, ix] += dY[i,k,oy,ox] * G[k, cb-vec, s, r]
                                        let gvec =
                                            g_vec_for_bwi(g, kb * V + kv, cb, s, r);
                                        let ddrow = &mut dd.data_mut()
                                            [ddoff + ix as usize * V..ddoff + ix as usize * V + V];
                                        bk.axpy_v(ddrow, gval, &gvec);
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
    }
    // Accounting (dense): every (i, oy, s-valid, ox, kv) issues R·C/V FMAs.
    let valid_rows: usize = (0..oh)
        .map(|oy| {
            (0..cfg.s)
                .filter(|&s| {
                    let iy = oy as isize * cfg.stride_p as isize + s as isize - cfg.pad_h as isize;
                    iy >= 0 && iy < cfg.h as isize
                })
                .count()
        })
        .sum();
    let sweeps = (cfg.n * valid_rows * cq_count * kb_count) as u64;
    stats.sweeps += sweeps;
    stats.loads_in += sweeps * ow as u64;
    // interior approximation for taps (exact per-element count is data-free
    // but boundary-clipped; totals only drive the model, keep exact):
    let mut taps_total = 0u64;
    for ox in 0..ow {
        for r in 0..cfg.r {
            let ix = ox as isize * cfg.stride_o as isize + r as isize - cfg.pad_w as isize;
            if ix >= 0 && ix < cfg.w as isize {
                taps_total += 1;
            }
        }
    }
    stats.fma_vec += sweeps * taps_total * V as u64 * qv as u64;
    // Per-task (i, y, qb) accumulator-row traffic.
    stats.loads_out += (cfg.n * cfg.h * cq_count * cfg.w * qv) as u64;
    stats.stores_out += (cfg.n * cfg.h * cq_count * cfg.w * qv) as u64;
    stats.filter_bytes_per_sweep =
        stats.filter_bytes_per_sweep.max((cfg.r * plan.q * V * 4) as u64);
}

/// Dense direct BWI over a **pre-transposed** filter (ISSUE 5 satellite):
/// `gt` is the channel-transposed copy ([`FilterTensor::transpose_channels`],
/// the same tensor the sparse BWI kernel keeps), so the FMA memory operand
/// is a contiguous C-vector straight from the tiled layout instead of the
/// V-element gather [`bwi`] performs per tap. This is the *fair* dense
/// baseline for BWI speedup numbers — the paper's tuned dense kernels also
/// hold a transposed filter copy — and it is bit-identical to [`bwi`]
/// (same FMAs, same order; only the operand addressing changes).
pub fn bwi_pre(
    cfg: &ConvConfig,
    dy: &ActTensor,
    gt: &FilterTensor,
    dd: &mut ActTensor,
    stats: &mut KernelStats,
) {
    bwi_pre_with(cfg, dy, gt, dd, simd::dispatch(), stats);
}

/// [`bwi_pre`] with an explicit backend (wallclock harness entry point).
pub fn bwi_pre_with(
    cfg: &ConvConfig,
    dy: &ActTensor,
    gt: &FilterTensor,
    dd: &mut ActTensor,
    bk: Backend,
    stats: &mut KernelStats,
) {
    cfg.validate().expect("invalid conv config");
    let (oh, ow) = (cfg.out_h(), cfg.out_w());
    debug_assert_eq!((dy.n, dy.c, dy.h, dy.w), (cfg.n, cfg.k, oh, ow));
    debug_assert_eq!((gt.k, gt.c, gt.s, gt.r), (cfg.c, cfg.k, cfg.s, cfg.r));
    debug_assert_eq!((dd.n, dd.c, dd.h, dd.w), (cfg.n, cfg.c, cfg.h, cfg.w));

    let plan = plan_fwd(cfg.c, cfg.r); // accumulators are C-vectors in BWI
    let qv = plan.q / V;
    let cq_count = cfg.c / plan.q;
    let kb_count = cfg.k / V;

    for i in 0..cfg.n {
        for oy in 0..oh {
            for s in 0..cfg.s {
                let iy = oy as isize * cfg.stride_p as isize + s as isize - cfg.pad_h as isize;
                if iy < 0 || iy >= cfg.h as isize {
                    continue;
                }
                let iy = iy as usize;
                for qb in 0..cq_count {
                    for kb in 0..kb_count {
                        for j in 0..qv {
                            let cb = qb * qv + j;
                            let ddoff = dd.vec_offset(i, cb, iy, 0);
                            for ox in 0..ow {
                                let dyvec = dy.vec(i, kb, oy, ox);
                                for kv in 0..V {
                                    let gval = dyvec[kv];
                                    for r in 0..cfg.r {
                                        let ix = ox as isize * cfg.stride_o as isize + r as isize
                                            - cfg.pad_w as isize;
                                        if ix < 0 || ix >= cfg.w as isize {
                                            continue;
                                        }
                                        // pre-transposed: the C-vector is a
                                        // contiguous slice of gt — no gather
                                        let gvec = gt.vec(cb, kb, s, r, kv);
                                        let ddrow = &mut dd.data_mut()
                                            [ddoff + ix as usize * V..ddoff + ix as usize * V + V];
                                        bk.axpy_v(ddrow, gval, gvec);
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
    }
    // Same accounting as the gathering baseline: identical FMA/load/store
    // counts, only the filter addressing differs.
    let valid_rows: usize = (0..oh)
        .map(|oy| {
            (0..cfg.s)
                .filter(|&s| {
                    let iy = oy as isize * cfg.stride_p as isize + s as isize - cfg.pad_h as isize;
                    iy >= 0 && iy < cfg.h as isize
                })
                .count()
        })
        .sum();
    let sweeps = (cfg.n * valid_rows * cq_count * kb_count) as u64;
    stats.sweeps += sweeps;
    stats.loads_in += sweeps * ow as u64;
    let mut taps_total = 0u64;
    for ox in 0..ow {
        for r in 0..cfg.r {
            let ix = ox as isize * cfg.stride_o as isize + r as isize - cfg.pad_w as isize;
            if ix >= 0 && ix < cfg.w as isize {
                taps_total += 1;
            }
        }
    }
    stats.fma_vec += sweeps * taps_total * V as u64 * qv as u64;
    stats.loads_out += (cfg.n * cfg.h * cq_count * cfg.w * qv) as u64;
    stats.stores_out += (cfg.n * cfg.h * cq_count * cfg.w * qv) as u64;
    stats.filter_bytes_per_sweep =
        stats.filter_bytes_per_sweep.max((cfg.r * plan.q * V * 4) as u64);
}

/// Dense BWW inner lane (same code shape as the sparse kernel's lane body
/// so the host baseline compiles to comparable SIMD).
#[inline(always)]
#[allow(clippy::too_many_arguments)]
fn bww_dense_lane(
    dy: &ActTensor,
    acc: &mut [f32],
    dval: f32,
    i: usize,
    qb: usize,
    qv: usize,
    oy: usize,
    taps: &[(usize, usize)],
    bk: Backend,
) {
    for &(r, ox) in taps {
        for j in 0..qv {
            let kb = qb * qv + j;
            let dyvec = dy.vec(i, kb, oy, ox);
            let a = &mut acc[(r * qv + j) * V..(r * qv + j) * V + V];
            bk.axpy_v(a, dval, dyvec);
        }
    }
}

/// Filter C-vector for BWI: G[k, cb·V .. cb·V+V, s, r] gathered from the
/// K-vector layout. The paper stores a transposed copy of G for BWI; we
/// reindex on the fly for functional clarity (host-perf BWI uses the
/// pre-transposed tensor via [`FilterTensor::transpose_for_bwi`]).
#[inline(always)]
fn g_vec_for_bwi<'a>(g: &'a FilterTensor, k: usize, cb: usize, s: usize, r: usize) -> [f32; V] {
    let mut out = [0.0f32; V];
    for (l, o) in out.iter_mut().enumerate() {
        *o = g.get(k, cb * V + l, s, r);
    }
    out
}

/// Dense direct backward-by-weights.
pub fn bww(
    cfg: &ConvConfig,
    d: &BatchTiledTensor,
    dy: &ActTensor,
    dg: &mut FilterTensor,
    stats: &mut KernelStats,
) {
    bww_with(cfg, d, dy, dg, simd::dispatch(), &mut Scratch::new(), stats);
}

/// [`bww`] with an explicit backend and reusable scratch.
pub fn bww_with(
    cfg: &ConvConfig,
    d: &BatchTiledTensor,
    dy: &ActTensor,
    dg: &mut FilterTensor,
    bk: Backend,
    scratch: &mut Scratch,
    stats: &mut KernelStats,
) {
    cfg.validate().expect("invalid conv config");
    assert!(cfg.n % V == 0, "BWW requires batch size multiple of V (§5.4)");
    let (oh, ow) = (cfg.out_h(), cfg.out_w());
    debug_assert_eq!((d.n, d.c, d.h, d.w), (cfg.n, cfg.c, cfg.h, cfg.w));
    debug_assert_eq!((dy.n, dy.c, dy.h, dy.w), (cfg.n, cfg.k, oh, ow));
    debug_assert_eq!((dg.k, dg.c, dg.s, dg.r), (cfg.k, cfg.c, cfg.s, cfg.r));

    let plan = plan_bww(cfg.k, cfg.r);
    let qv = plan.q / V;
    let kq_count = cfg.k / plan.q;

    // Loop order per Algorithm 5 (dense): i-tile, y (output row), s, q, c;
    // row sweep over input columns; accumulators dG[r][q-tile] resident.
    let taps = super::sparse_bww::bww_col_taps(cfg);
    let acc = scratch.acc(cfg.r * qv * V);
    for nb in 0..cfg.n / V {
        for oy in 0..oh {
            for s in 0..cfg.s {
                let iy = oy as isize * cfg.stride_p as isize + s as isize - cfg.pad_h as isize;
                if iy < 0 || iy >= cfg.h as isize {
                    continue;
                }
                let iy = iy as usize;
                for qb in 0..kq_count {
                    for c in 0..cfg.c {
                        acc.iter_mut().for_each(|a| *a = 0.0);
                        for ix in 0..cfg.w {
                            let tap = &taps[ix];
                            if tap.is_empty() {
                                continue;
                            }
                            let dvec = d.vec(nb, c, iy, ix);
                            for nv in 0..V {
                                bww_dense_lane(
                                    dy,
                                    acc,
                                    dvec[nv],
                                    nb * V + nv,
                                    qb,
                                    qv,
                                    oy,
                                    tap,
                                    bk,
                                );
                            }
                        }
                        // Fold the sweep accumulators into dG (scale 1.0:
                        // fma(a, 1, g) rounds once on the sum — bit-equal
                        // to a plain add).
                        for r in 0..cfg.r {
                            for j in 0..qv {
                                let kb = qb * qv + j;
                                let a = &acc[(r * qv + j) * V..(r * qv + j) * V + V];
                                let gv = dg.vec_mut(kb, c / V, s, r, c % V);
                                bk.axpy_v(gv, 1.0, a);
                            }
                        }
                        stats.sweeps += 1;
                        stats.loads_out += (cfg.r * qv) as u64;
                        stats.stores_out += (cfg.r * qv) as u64;
                    }
                }
            }
        }
    }
    // FMA / load accounting (dense): per sweep, every valid (ox, r) tap
    // issues V lanes × qv vector FMAs, with the dY operand from memory.
    let taps_total: u64 = taps.iter().map(|t| t.len() as u64).sum();
    stats.fma_vec += stats.sweeps * taps_total * (V as u64) * qv as u64;
    stats.loads_in += stats.sweeps * taps_total;
    stats.filter_bytes_per_sweep =
        stats.filter_bytes_per_sweep.max((cfg.r * plan.q * V * 4) as u64);
}

#[cfg(test)]
mod tests {
    use super::super::reference;
    use super::*;
    use crate::tensor::allclose;
    use crate::util::prng::Xorshift;

    fn setup(cfg: &ConvConfig, seed: u64) -> (ActTensor, FilterTensor) {
        let mut rng = Xorshift::new(seed);
        let mut d = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
        d.fill_uniform(&mut rng, -1.0, 1.0);
        let mut g = FilterTensor::zeros(cfg.k, cfg.c, cfg.s, cfg.r);
        g.fill_uniform(&mut rng, -0.5, 0.5);
        (d, g)
    }

    #[test]
    fn fwd_matches_reference_3x3() {
        for stride in [1, 2] {
            let cfg = ConvConfig::square(2, 32, 32, 8, 3, stride);
            let (d, g) = setup(&cfg, 11);
            let mut y = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
            let mut st = KernelStats::new();
            fwd(&cfg, &d, &g, &mut y, &mut st);
            let yref = reference::conv_fwd(&cfg, &d.to_nchw(), &g.to_kcsr());
            assert!(
                allclose(&y.to_nchw(), &yref, 1e-4, 1e-5),
                "stride={stride} mismatch"
            );
            assert!(st.fma_vec > 0);
            assert_eq!(st.fma_vec_skipped, 0);
        }
    }

    #[test]
    fn fwd_matches_reference_1x1() {
        let cfg = ConvConfig::square(2, 64, 32, 7, 1, 1);
        let (d, g) = setup(&cfg, 13);
        let mut y = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
        let mut st = KernelStats::new();
        fwd(&cfg, &d, &g, &mut y, &mut st);
        let yref = reference::conv_fwd(&cfg, &d.to_nchw(), &g.to_kcsr());
        assert!(allclose(&y.to_nchw(), &yref, 1e-4, 1e-5));
    }

    #[test]
    fn bwi_matches_reference() {
        for stride in [1, 2] {
            let cfg = ConvConfig::square(2, 32, 16, 8, 3, stride);
            let mut rng = Xorshift::new(17);
            let mut dy = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
            dy.fill_uniform(&mut rng, -1.0, 1.0);
            let mut g = FilterTensor::zeros(cfg.k, cfg.c, cfg.s, cfg.r);
            g.fill_uniform(&mut rng, -0.5, 0.5);
            let mut dd = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
            let mut st = KernelStats::new();
            bwi(&cfg, &dy, &g, &mut dd, &mut st);
            let ddref = reference::conv_bwi(&cfg, &dy.to_nchw(), &g.to_kcsr());
            assert!(
                allclose(&dd.to_nchw(), &ddref, 1e-4, 1e-5),
                "stride={stride} mismatch"
            );
        }
    }

    /// The pre-transposed dense BWI issues the same FMAs in the same order
    /// as the gathering baseline — bit-identical outputs and identical
    /// counters — while reading contiguous C-vectors.
    #[test]
    fn bwi_pre_bit_matches_gathering_baseline() {
        for stride in [1, 2] {
            let cfg = ConvConfig::square(2, 32, 16, 8, 3, stride);
            let mut rng = Xorshift::new(29);
            let mut dy = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
            dy.fill_uniform(&mut rng, -1.0, 1.0);
            let mut g = FilterTensor::zeros(cfg.k, cfg.c, cfg.s, cfg.r);
            g.fill_uniform(&mut rng, -0.5, 0.5);
            let gt = g.transpose_channels();

            let mut dd_gather = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
            let mut st_gather = KernelStats::new();
            bwi(&cfg, &dy, &g, &mut dd_gather, &mut st_gather);

            let mut dd_pre = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
            let mut st_pre = KernelStats::new();
            bwi_pre(&cfg, &dy, &gt, &mut dd_pre, &mut st_pre);

            assert_eq!(dd_pre.data(), dd_gather.data(), "stride={stride}");
            assert_eq!(st_pre, st_gather, "stride={stride}");

            let ddref = reference::conv_bwi(&cfg, &dy.to_nchw(), &g.to_kcsr());
            assert!(allclose(&dd_pre.to_nchw(), &ddref, 1e-4, 1e-5), "stride={stride}");
        }
    }

    #[test]
    fn bww_matches_reference() {
        for stride in [1, 2] {
            let cfg = ConvConfig::square(16, 32, 32, 6, 3, stride);
            let mut rng = Xorshift::new(19);
            let mut dsrc = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
            dsrc.fill_uniform(&mut rng, -1.0, 1.0);
            let d = BatchTiledTensor::from_act(&dsrc);
            let mut dy = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
            dy.fill_uniform(&mut rng, -1.0, 1.0);
            let mut dg = FilterTensor::zeros(cfg.k, cfg.c, cfg.s, cfg.r);
            let mut st = KernelStats::new();
            bww(&cfg, &d, &dy, &mut dg, &mut st);
            let dgref = reference::conv_bww(&cfg, &dsrc.to_nchw(), &dy.to_nchw());
            assert!(
                allclose(&dg.to_kcsr(), &dgref, 1e-3, 1e-4),
                "stride={stride} mismatch"
            );
        }
    }

    #[test]
    fn fwd_fma_count_matches_formula_when_unpadded() {
        // With no padding and unit stride, every tap is valid:
        // fma_vec == N·(K/V)·H'·W'·C·S·R
        let mut cfg = ConvConfig::square(1, 16, 32, 6, 3, 1);
        cfg.pad_h = 0;
        cfg.pad_w = 0;
        let (d, g) = setup(&cfg, 23);
        let mut y = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
        let mut st = KernelStats::new();
        fwd(&cfg, &d, &g, &mut y, &mut st);
        assert_eq!(st.fma_vec, cfg.fwd_vec_fmas());
    }
}
