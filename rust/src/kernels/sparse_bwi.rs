//! SparseTrain backward propagation by input (§3.3).
//!
//! BWI mirrors FWD with the roles of the tensors swapped: the sweep scans
//! ∂L/∂Y (which carries the ReLU sparsity when no BatchNorm intervenes —
//! §2.3) and scatters into ∂L/∂D, with the filters channel-transposed so
//! the FMA memory operand is a C-vector. The zero-check and the FMA groups
//! run through the dispatched [`Backend`] primitives, and the column-tap
//! table is computed **once per launch** ([`bwi_col_taps`]) instead of per
//! task — the per-task hot path allocates nothing.
//!
//! Differences from FWD the paper calls out:
//! * with row stride `O > 1`, `O·Q/V` new ∂L/∂D vectors enter the register
//!   buffer per processed ∂L/∂Y vector (vs `Q/V` in FWD) — BWI becomes
//!   cache-bandwidth-bound on strided layers (§5.1);
//! * ignoring boundaries, a ∂L/∂Y element always affects the full
//!   `T = R·Q/V` vectors (no stride-induced tap gaps).

use super::regalloc::{plan_fwd, RegPlan};
use super::simd::{self, Backend};
use super::{ConvConfig, KernelStats, Scratch, SkipMode};
use crate::tensor::{ActTensor, FilterTensor, RowTileMut};
use crate::V;

/// Column taps for a BWI sweep: for each output column `ox`, the (r, x)
/// pairs with `ox·O + r − pad_w = x` inside the input. Identical for every
/// `s`, so the driver computes it once per launch and passes it to every
/// task (the BWI analogue of [`super::sparse_bww::bww_col_taps`]).
pub fn bwi_col_taps(cfg: &ConvConfig) -> Vec<Vec<(usize, usize)>> {
    let ow = cfg.out_w();
    (0..ow)
        .map(|ox| {
            (0..cfg.r)
                .filter_map(|r| {
                    let x = ox as isize * cfg.stride_o as isize + r as isize - cfg.pad_w as isize;
                    (x >= 0 && x < cfg.w as isize).then_some((r, x as usize))
                })
                .collect()
        })
        .collect()
}

/// SparseTrain BWI. `gt` is the channel-transposed filter tensor
/// ([`FilterTensor::transpose_channels`]; dims `[C][K][S][R]` logically).
/// `dd` must be zero-initialized. Uses the process-wide dispatched
/// [`Backend`] and a fresh [`Scratch`].
///
/// Like FWD, the serial driver iterates the same per-task views the
/// parallel scheduler distributes ([`ActTensor::par_row_tiles_mut`] over
/// `dd`), in the same `(i, iy, cb)` order.
pub fn bwi(
    cfg: &ConvConfig,
    dy: &ActTensor,
    gt: &FilterTensor,
    dd: &mut ActTensor,
    mode: SkipMode,
    stats: &mut KernelStats,
) {
    bwi_with(cfg, dy, gt, dd, mode, simd::dispatch(), &mut Scratch::new(), stats);
}

/// [`bwi`] with an explicit backend and reusable scratch — the zero-alloc
/// entry point the wallclock harness and the parity suite drive.
#[allow(clippy::too_many_arguments)]
pub fn bwi_with(
    cfg: &ConvConfig,
    dy: &ActTensor,
    gt: &FilterTensor,
    dd: &mut ActTensor,
    mode: SkipMode,
    bk: Backend,
    scratch: &mut Scratch,
    stats: &mut KernelStats,
) {
    cfg.validate().expect("invalid conv config");
    let (oh, ow) = (cfg.out_h(), cfg.out_w());
    debug_assert_eq!((dy.n, dy.c, dy.h, dy.w), (cfg.n, cfg.k, oh, ow));
    debug_assert_eq!((gt.k, gt.c, gt.s, gt.r), (cfg.c, cfg.k, cfg.s, cfg.r));
    debug_assert_eq!((dd.n, dd.c, dd.h, dd.w), (cfg.n, cfg.c, cfg.h, cfg.w));

    let plan = plan_fwd(cfg.c, cfg.r); // accumulators are C-vectors
    let taps = bwi_col_taps(cfg);
    for view in dd.par_row_tiles_mut(plan.q / V).iter_mut() {
        bwi_task(cfg, dy, gt, view, &taps, mode, &plan, bk, scratch, stats);
    }
    stats.filter_bytes_per_sweep =
        stats.filter_bytes_per_sweep.max((cfg.s * cfg.r * plan.q * V * 4) as u64);
}

/// Per-task body: one ∂L/∂D row × one Q tile of input channels. The task
/// scatters only into its own [`RowTileMut`] view of `dd` — the disjoint
/// `(view.i, view.y, view.qb)` slice — so parallel tasks cannot alias.
/// `taps` is the launch-wide [`bwi_col_taps`] table and `plan` the
/// driver's register plan (both hoisted out of the per-task hot path).
#[allow(clippy::too_many_arguments)]
pub fn bwi_task(
    cfg: &ConvConfig,
    dy: &ActTensor,
    gt: &FilterTensor,
    view: &mut RowTileMut<'_>,
    taps: &[Vec<(usize, usize)>],
    mode: SkipMode,
    plan: &RegPlan,
    bk: Backend,
    scratch: &mut Scratch,
    stats: &mut KernelStats,
) {
    debug_assert_eq!(*plan, plan_fwd(cfg.c, cfg.r), "plan must come from the driver's plan_fwd");
    let qv = plan.q / V;
    debug_assert_eq!(view.tiles(), qv, "view tiling must match the register plan");
    let (i, y, qb) = (view.i, view.y, view.qb);
    let (oh, ow) = (cfg.out_h(), cfg.out_w());
    debug_assert_eq!(taps.len(), ow, "taps must match the layer's output width");
    let kb_count = cfg.k / V;

    // Row accumulator over the full input width (reused across tasks);
    // whole-row memcpy beats per-vector copy_v calls for the load/store,
    // and acc_uninit skips the zero-fill the copy would overwrite anyway.
    let acc = scratch.acc_uninit(cfg.w * qv * V);
    for j in 0..qv {
        acc[j * cfg.w * V..(j + 1) * cfg.w * V].copy_from_slice(view.row(j));
    }

    // Geometry: output rows (oy, s) feeding input row y.
    for s in 0..cfg.s {
        let t = y as isize + cfg.pad_h as isize - s as isize;
        if t < 0 || t % cfg.stride_p as isize != 0 {
            continue;
        }
        let oy = (t / cfg.stride_p as isize) as usize;
        if oy >= oh {
            continue;
        }

        for kb in 0..kb_count {
            stats.sweeps += 1;
            stats.loads_in += ow as u64;
            for ox in 0..ow {
                let dyvec = dy.vec_arr(i, kb, oy, ox);
                let tap = &taps[ox];
                if tap.is_empty() {
                    continue;
                }
                let mask = bk.nonzero_mask(dyvec);
                let nonzeros = mask.count_ones() as usize;
                stats.record_check(nonzeros);
                let t_here = (tap.len() * qv) as u64;
                stats.fma_vec += nonzeros as u64 * t_here;
                stats.fma_vec_skipped += (V - nonzeros) as u64 * t_here;

                match mode {
                    SkipMode::Dense => {
                        for kv in 0..V {
                            fma_lane(gt, acc, dyvec[kv], qb, qv, kb, s, kv, tap, cfg.w, bk);
                        }
                        stats.fma_vec += (V - nonzeros) as u64 * t_here;
                        stats.fma_vec_skipped -= (V - nonzeros) as u64 * t_here;
                    }
                    SkipMode::PerLaneBranch => {
                        for kv in 0..V {
                            if mask & (1 << kv) != 0 {
                                fma_lane(gt, acc, dyvec[kv], qb, qv, kb, s, kv, tap, cfg.w, bk);
                            }
                        }
                        stats.int_ops += V as u64;
                    }
                    SkipMode::MaskLoop => {
                        let mut m = mask;
                        while m != 0 {
                            let kv = m.trailing_zeros() as usize;
                            fma_lane(gt, acc, dyvec[kv], qb, qv, kb, s, kv, tap, cfg.w, bk);
                            m &= m - 1;
                        }
                        stats.int_ops += 2 + 8 * nonzeros as u64;
                    }
                }
            }
        }
    }

    for j in 0..qv {
        view.row_mut(j).copy_from_slice(&acc[j * cfg.w * V..(j + 1) * cfg.w * V]);
    }
    // §3.3: the register buffer cycles O× faster — the accumulator row is
    // W wide for an ow-wide sweep, i.e. O·Q/V vectors per input element.
    stats.loads_out += (cfg.w * qv) as u64;
    stats.stores_out += (cfg.w * qv) as u64;
}

/// FMAs for one nonzero ∂L/∂Y lane: `gt` C-vector operand from memory,
/// issued through [`Backend::axpy_v`]. Strength-reduced filter indexing
/// (see `sparse_fwd::fma_lane`).
#[inline(always)]
#[allow(clippy::too_many_arguments)]
fn fma_lane(
    gt: &FilterTensor,
    acc: &mut [f32],
    dyval: f32,
    qb: usize,
    qv: usize,
    kb: usize,
    s: usize,
    kv: usize,
    taps: &[(usize, usize)],
    w: usize,
    bk: Backend,
) {
    let gdata = gt.data();
    let cb_stride = gt.c_blocks() * gt.s * gt.r * V * V;
    let lane_base = ((kb * gt.s + s) * gt.r) * V * V + kv * V;
    for j in 0..qv {
        let cb = qb * qv + j;
        let cb_base = cb * cb_stride + lane_base;
        let base = j * w * V;
        for &(r, x) in taps {
            let go = cb_base + r * V * V;
            let gvec = &gdata[go..go + V];
            let a = &mut acc[base + x * V..base + x * V + V];
            bk.axpy_v(a, dyval, gvec);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::reference;
    use super::*;
    use crate::tensor::allclose;
    use crate::util::prng::Xorshift;

    fn setup(cfg: &ConvConfig, sparsity: f64, seed: u64) -> (ActTensor, FilterTensor) {
        let mut rng = Xorshift::new(seed);
        let mut dy = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
        dy.fill_relu_sparse(&mut rng, sparsity);
        // gradients flowing back are signed; flip signs of nonzeros
        for v in dy.data_mut().iter_mut() {
            if *v != 0.0 && rng.bernoulli(0.5) {
                *v = -*v;
            }
        }
        let mut g = FilterTensor::zeros(cfg.k, cfg.c, cfg.s, cfg.r);
        g.fill_uniform(&mut rng, -0.5, 0.5);
        (dy, g)
    }

    fn run_and_check(cfg: &ConvConfig, sparsity: f64, mode: SkipMode) -> KernelStats {
        let (dy, g) = setup(cfg, sparsity, 303);
        let gt = g.transpose_channels();
        let mut dd = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
        let mut st = KernelStats::new();
        bwi(cfg, &dy, &gt, &mut dd, mode, &mut st);
        let ddref = reference::conv_bwi(cfg, &dy.to_nchw(), &g.to_kcsr());
        assert!(allclose(&dd.to_nchw(), &ddref, 1e-4, 1e-5), "mode={mode:?}");
        st
    }

    #[test]
    #[cfg_attr(miri, ignore = "too slow under miri; miri_* tests cover the reduced set")]
    fn matches_reference_all_modes() {
        let cfg = ConvConfig::square(2, 32, 32, 8, 3, 1);
        for mode in [SkipMode::Dense, SkipMode::PerLaneBranch, SkipMode::MaskLoop] {
            run_and_check(&cfg, 0.5, mode);
        }
    }

    #[test]
    #[cfg_attr(miri, ignore = "too slow under miri; miri_* tests cover the reduced set")]
    fn matches_reference_strided() {
        // resnet-style stride-2 3x3
        let cfg = ConvConfig::square(2, 32, 32, 8, 3, 2);
        run_and_check(&cfg, 0.5, SkipMode::MaskLoop);
    }

    #[test]
    #[cfg_attr(miri, ignore = "too slow under miri; miri_* tests cover the reduced set")]
    fn matches_reference_1x1() {
        let cfg = ConvConfig::square(2, 32, 64, 7, 1, 1);
        run_and_check(&cfg, 0.4, SkipMode::MaskLoop);
    }

    #[test]
    #[cfg_attr(miri, ignore = "too slow under miri; miri_* tests cover the reduced set")]
    fn matches_reference_rect_filter() {
        let cfg = ConvConfig {
            n: 1,
            c: 16,
            k: 32,
            h: 7,
            w: 9,
            s: 1,
            r: 3,
            stride_p: 1,
            stride_o: 1,
            pad_h: 0,
            pad_w: 1,
        };
        run_and_check(&cfg, 0.3, SkipMode::MaskLoop);
    }

    #[test]
    #[cfg_attr(miri, ignore = "too slow under miri; miri_* tests cover the reduced set")]
    fn skip_fraction_tracks_dy_sparsity() {
        let cfg = ConvConfig::square(2, 32, 64, 8, 3, 1);
        let st = run_and_check(&cfg, 0.7, SkipMode::MaskLoop);
        assert!((st.skip_fraction() - 0.7).abs() < 0.06, "{}", st.skip_fraction());
    }

    #[test]
    fn interior_elements_hit_full_t() {
        // §3.3: away from boundaries each ∂L/∂Y element affects T vectors.
        // With an all-nonzero dY and no padding truncation in the interior,
        // fma per check at interior == R·Q/V · 1 lane... verified via totals:
        let cfg = ConvConfig::square(1, 16, 16, 8, 3, 1);
        let (mut dy, g) = setup(&cfg, 0.0, 9);
        for v in dy.data_mut().iter_mut() {
            *v = 1.0;
        }
        let gt = g.transpose_channels();
        let mut dd = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
        let mut st = KernelStats::new();
        bwi(&cfg, &dy, &gt, &mut dd, SkipMode::MaskLoop, &mut st);
        assert_eq!(st.fma_vec_skipped, 0);
        assert!(st.fma_vec > 0);
    }

    /// The hoisted tap table matches the geometry the per-sweep code used
    /// to recompute: every (ox, r) pair lands on a valid input column.
    #[test]
    fn col_taps_match_geometry() {
        for (hw, rs, stride, extra_pad) in [(8, 3, 1, 0), (9, 3, 2, 0), (7, 5, 1, 1)] {
            let mut cfg = ConvConfig::square(1, 16, 16, hw, rs, stride);
            cfg.pad_w += extra_pad;
            let taps = bwi_col_taps(&cfg);
            assert_eq!(taps.len(), cfg.out_w());
            for (ox, tap) in taps.iter().enumerate() {
                for &(r, x) in tap {
                    assert!(r < cfg.r && x < cfg.w);
                    assert_eq!(
                        ox as isize * cfg.stride_o as isize + r as isize - cfg.pad_w as isize,
                        x as isize
                    );
                }
            }
        }
    }

    /// Reduced-geometry Miri gate: the view-based task decomposition (the
    /// slices `bwi_task` scatters into) equals the whole-kernel run on a
    /// layer small enough for the interpreter.
    #[test]
    fn miri_reduced_view_tasks_cover_whole() {
        let cfg = ConvConfig::square(1, 16, 16, 4, 3, 1);
        let (dy, g) = setup(&cfg, 0.5, 23);
        let gt = g.transpose_channels();
        let plan = plan_fwd(cfg.c, cfg.r);
        let taps = bwi_col_taps(&cfg);
        let bk = simd::dispatch();
        let mut dd1 = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
        let mut st = KernelStats::new();
        bwi(&cfg, &dy, &gt, &mut dd1, SkipMode::MaskLoop, &mut st);
        let mut dd2 = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
        let mut st2 = KernelStats::new();
        let mut scratch = Scratch::new();
        let mode = SkipMode::MaskLoop;
        for view in dd2.par_row_tiles_mut(plan.q / V).iter_mut().rev() {
            bwi_task(&cfg, &dy, &gt, view, &taps, mode, &plan, bk, &mut scratch, &mut st2);
        }
        assert_eq!(dd1.data(), dd2.data());
        assert_eq!(st.fma_vec, st2.fma_vec);
        assert_eq!(st.zero_checks, st2.zero_checks);
    }
}
