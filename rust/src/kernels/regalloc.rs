//! Register-budget planning (§3.2.3, Table 3).
//!
//! The paper JIT-generates kernels whose output accumulators live in zmm
//! registers: `T = R·Q/V` output vectors per row sweep, plus one register
//! for the broadcast input element and one holding zeros for the vector
//! compare — a budget of 30 of the 32 zmm registers. When spare registers
//! remain, the loads of the *next* input element's output vectors are
//! pipelined (cyclic renaming over `R+1` instead of `R` positions).
//!
//! This module reproduces that selection exactly; the chosen `Q` also
//! drives the output-channel tiling of the Rust kernels and the parallel
//! task count `N·H·K/Q` of the coordinator.

use crate::V;

/// Total architectural vector registers on the modeled CPU.
pub const TOTAL_REGS: usize = 32;
/// Registers reserved for the broadcast input element and the zero vector.
pub const RESERVED_REGS: usize = 2;
/// Budget available for output accumulators.
pub const REG_BUDGET: usize = TOTAL_REGS - RESERVED_REGS;

/// A register plan for one row-sweep kernel.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RegPlan {
    /// Output-channel tile size (factor of K, multiple of V).
    pub q: usize,
    /// Skippable FMAs per zero check: `T = R·Q/V`.
    pub t: usize,
    /// Whether next-element output loads are pipelined (§3.2.3).
    pub pipelined: bool,
    /// Registers used for accumulators: `(R + pipelined)·Q/V`.
    pub registers: usize,
}

/// All candidate Q values: multiples of V that divide K.
fn q_candidates(k: usize) -> Vec<usize> {
    (1..=k / V).map(|m| m * V).filter(|q| k % q == 0).collect()
}

/// Pick the optimal (Q, pipelined) for a FWD/BWI row sweep of filter width
/// `r` over `k` output channels (Table 3 selection rule): maximize register
/// utilization under the budget; prefer pipelined at equal utilization
/// (the paper measured Q=256 unpipelined slower than Q=128 pipelined for
/// R=1); prefer the larger Q at remaining ties.
pub fn plan_fwd(k: usize, r: usize) -> RegPlan {
    assert!(k % V == 0 && k > 0, "K must be a positive multiple of V");
    let mut best: Option<RegPlan> = None;
    for q in q_candidates(k) {
        let t = r * q / V;
        if t > REG_BUDGET {
            continue;
        }
        for pipelined in [false, true] {
            let registers = (r + usize::from(pipelined)) * q / V;
            if registers > REG_BUDGET {
                continue;
            }
            let cand = RegPlan { q, t, pipelined, registers };
            let better = match &best {
                None => true,
                Some(b) => {
                    (cand.registers, cand.pipelined as usize, cand.q)
                        > (b.registers, b.pipelined as usize, b.q)
                }
            };
            if better {
                best = Some(cand);
            }
        }
    }
    best.expect("at least Q=V must fit: R too large for the register budget")
}

/// BWW plan (§3.4): the dG accumulators stay register-resident for the whole
/// sweep, no cyclic renaming, no pipelining — just the largest `Q` with
/// `T = R·Q/V ≤ budget`.
pub fn plan_bww(k: usize, r: usize) -> RegPlan {
    assert!(k % V == 0 && k > 0, "K must be a positive multiple of V");
    let mut best: Option<RegPlan> = None;
    for q in q_candidates(k) {
        let t = r * q / V;
        if t > REG_BUDGET {
            continue;
        }
        let cand = RegPlan { q, t, pipelined: false, registers: t };
        if best.map_or(true, |b| (cand.t, cand.q) > (b.t, b.q)) {
            best = Some(cand);
        }
    }
    best.expect("at least Q=V must fit: R too large for the register budget")
}

/// The row-sweep unroll factor: the cyclic renaming repeats every `R`
/// iterations (`R+1` when pipelined) — §3.2.3.
pub fn unroll_factor(plan: &RegPlan, r: usize) -> usize {
    if plan.pipelined {
        r + 1
    } else {
        r
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Reproduces Table 3 of the paper: K = 256, V = 16.
    #[test]
    fn table3_k256() {
        let p1 = plan_fwd(256, 1);
        assert_eq!((p1.q, p1.t, p1.pipelined, p1.registers), (128, 8, true, 16));

        let p3 = plan_fwd(256, 3);
        assert_eq!((p3.q, p3.t, p3.pipelined, p3.registers), (128, 24, false, 24));

        let p5 = plan_fwd(256, 5);
        assert_eq!((p5.q, p5.t, p5.pipelined, p5.registers), (64, 20, true, 24));
    }

    #[test]
    fn never_exceeds_budget() {
        for k in [16, 64, 128, 256, 512, 1024, 2048] {
            for r in [1, 3, 5, 7] {
                let p = plan_fwd(k, r);
                assert!(p.registers <= REG_BUDGET, "k={k} r={r} plan={p:?}");
                assert!(p.t <= REG_BUDGET);
                assert_eq!(k % p.q, 0);
                assert_eq!(p.q % V, 0);
                let b = plan_bww(k, r);
                assert!(b.t <= REG_BUDGET);
                assert!(!b.pipelined);
            }
        }
    }

    #[test]
    fn small_k_uses_whole_k() {
        // K=64, R=3: T = 3*64/16 = 12 ≤ 30 → Q = 64 (whole K);
        // pipelined would use 4*4 = 16 regs, also legal, preferred at
        // equal-or-better utilization.
        let p = plan_fwd(64, 3);
        assert_eq!(p.q, 64);
        assert!(p.registers <= REG_BUDGET);
        // vgg1_2-style observation of the paper (§5.1): C=K=64 gives only
        // 12 skippable FMAs per check.
        assert_eq!(plan_fwd(64, 3).t.min(12), 12);
    }

    #[test]
    fn bww_plan_maximizes_t() {
        // K=256, R=3 → T = 24 at Q=128 (48 at Q=256 exceeds 30).
        let p = plan_bww(256, 3);
        assert_eq!((p.q, p.t), (128, 24));
        // 1x1: T = Q/V → Q can reach 480... but Q|K caps at 256, T=16.
        let p = plan_bww(256, 1);
        assert_eq!((p.q, p.t), (256, 16));
    }

    #[test]
    fn unroll_factor_follows_pipelining() {
        let p = plan_fwd(256, 3);
        assert_eq!(unroll_factor(&p, 3), 3);
        let p = plan_fwd(256, 5);
        assert_eq!(unroll_factor(&p, 5), 6);
    }
}
