//! Convolution kernels: the paper's SparseTrain scheme plus every baseline
//! it is compared against.
//!
//! Every kernel is *functional* (computes real numerics over the tiled
//! tensor layouts, unit-tested against the scalar reference in
//! [`reference`]) and *accounted*: it fills a [`KernelStats`] with the
//! micro-op counts (vector FMAs issued/skipped, vector loads/stores per
//! working set, zero-check mask statistics, integer overhead ops) that the
//! Skylake-X model in [`crate::sim`] turns into cycle estimates.
//!
//! | module | paper name | role |
//! |---|---|---|
//! | [`simd`] | §3.2.1/§3.2.4 machine ops | explicit-SIMD primitive backend (runtime-dispatched) |
//! | [`direct`] | `direct` (MKL-DNN) | dense baseline, all three components |
//! | [`sparse_fwd`] | SparseTrain FWD (Alg. 2+3) | sparse forward |
//! | [`sparse_bwi`] | SparseTrain BWI (§3.3) | sparse backward-by-input |
//! | [`sparse_bww`] | SparseTrain BWW (Alg. 5) | sparse backward-by-weights |
//! | [`gemm`] | §5.1 sgemm | blocked, threaded, SIMD-dispatched GEMM (im2col + op-router `dot`) |
//! | [`im2col`] | `im2col` | lowering + GEMM baseline |
//! | [`winograd`] | `winograd` | F(2×2, 3×3) baseline (3×3, stride 1) |
//! | [`onebyone`] | `1x1` | specialized reduction kernel for 1×1 layers |
//! | [`regalloc`] | Table 3 | Q/T/pipelining register-budget selection |
//! | [`layers`] | — | ReLU / BatchNorm / pooling / FC / loss substrates |
//! | [`reference`] | — | scalar 7-loop oracle for tests |
//!
//! The SparseTrain and `direct` hot loops no longer carry per-lane scalar
//! arithmetic: the zero-check, the FMA-group body and the V-vector copies
//! all go through the three [`simd::Backend`] primitives, resolved once per
//! process (AVX-512F where available and built, AVX2+FMA on other x86-64,
//! NEON on AArch64, portable scalar under Miri and everywhere else). All
//! backends are bit-identical by construction — a fused multiply-add and an
//! IEEE `!= 0.0` compare — so the choice never changes numerics, only
//! wall-clock.

pub mod direct;
pub mod gemm;
pub mod im2col;
pub mod layers;
pub mod onebyone;
pub mod reference;
pub mod regalloc;
pub mod simd;
pub mod sparse_bwi;
pub mod sparse_bww;
pub mod sparse_fwd;
pub mod stats_model;
pub mod winograd;

use crate::V;

/// A convolution layer configuration (Table 1 symbols).
///
/// `h`/`w` are the *input* spatial dims; `s`/`r` the filter dims;
/// `stride_p`/`stride_o` the vertical/horizontal strides; `pad_h`/`pad_w`
/// the (symmetric) zero padding.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConvConfig {
    pub n: usize,
    pub c: usize,
    pub k: usize,
    pub h: usize,
    pub w: usize,
    pub s: usize,
    pub r: usize,
    pub stride_p: usize,
    pub stride_o: usize,
    pub pad_h: usize,
    pub pad_w: usize,
}

impl ConvConfig {
    /// A square-image, square-filter config with "same"-style padding
    /// (pad = (filter-1)/2), matching the paper's Table 2 rows.
    pub fn square(n: usize, c: usize, k: usize, hw: usize, rs: usize, stride: usize) -> ConvConfig {
        ConvConfig {
            n,
            c,
            k,
            h: hw,
            w: hw,
            s: rs,
            r: rs,
            stride_p: stride,
            stride_o: stride,
            pad_h: (rs - 1) / 2,
            pad_w: (rs - 1) / 2,
        }
    }

    /// Output height H'.
    pub fn out_h(&self) -> usize {
        (self.h + 2 * self.pad_h - self.s) / self.stride_p + 1
    }

    /// Output width W'.
    pub fn out_w(&self) -> usize {
        (self.w + 2 * self.pad_w - self.r) / self.stride_o + 1
    }

    /// FLOPs (multiply+add counted as 2) of the dense forward convolution.
    pub fn fwd_flops(&self) -> u64 {
        2 * (self.n * self.k * self.out_h() * self.out_w() * self.c * self.s * self.r) as u64
    }

    /// Dense V-wide FMA count for FWD (vectorized over K).
    pub fn fwd_vec_fmas(&self) -> u64 {
        (self.n * (self.k / V) * self.out_h() * self.out_w() * self.c * self.s * self.r) as u64
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.c % V != 0 {
            return Err(format!("C={} not a multiple of V={V}", self.c));
        }
        if self.k % V != 0 {
            return Err(format!("K={} not a multiple of V={V}", self.k));
        }
        if self.s == 0 || self.r == 0 || self.stride_o == 0 || self.stride_p == 0 {
            return Err("degenerate filter/stride".into());
        }
        if self.h + 2 * self.pad_h < self.s || self.w + 2 * self.pad_w < self.r {
            return Err("filter larger than padded input".into());
        }
        Ok(())
    }
}

/// Which training component a kernel run implements.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Component {
    /// Forward propagation.
    Fwd,
    /// Backward propagation by input (∂L/∂D).
    Bwi,
    /// Backward propagation by weights (∂L/∂G).
    Bww,
}

impl Component {
    pub const ALL: [Component; 3] = [Component::Fwd, Component::Bwi, Component::Bww];

    pub fn name(&self) -> &'static str {
        match self {
            Component::Fwd => "FWD",
            Component::Bwi => "BWI",
            Component::Bww => "BWW",
        }
    }
}

/// Zero-check/skip strategy (§3.2.4 ablation).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SkipMode {
    /// No skipping at all: behave densely (still the SparseTrain loop
    /// structure, but every lane is processed). Isolates loop-order cost.
    Dense,
    /// Algorithm 2: a conditional branch per lane of the mask.
    PerLaneBranch,
    /// Algorithm 3: popcount + tzcnt loop over set lanes (default).
    #[default]
    MaskLoop,
}

/// Reusable per-worker scratch memory for the kernel task bodies.
///
/// Every task used to allocate its row/sweep accumulator with
/// `vec![0.0f32; ..]` — one heap round-trip per task (and per *sweep* in
/// BWW). A `Scratch` is created once per worker thread (plumbed through
/// [`crate::util::threadpool::ThreadPool::for_chunk_slices_with`]) or once
/// per serial kernel launch, and [`Scratch::acc`] hands out a zeroed
/// accumulator that reuses the grown allocation — the hot path performs no
/// allocation after the first task.
#[derive(Debug, Default)]
pub struct Scratch {
    buf: Vec<f32>,
}

impl Scratch {
    pub fn new() -> Scratch {
        Scratch { buf: Vec::new() }
    }

    /// A zero-filled accumulator of length `n`, reusing the allocation
    /// (equivalent to a fresh `vec![0.0; n]` without the heap traffic).
    #[inline]
    pub fn acc(&mut self, n: usize) -> &mut [f32] {
        self.buf.clear();
        self.buf.resize(n, 0.0);
        &mut self.buf
    }

    /// An accumulator of length `n` with **unspecified contents** — for
    /// call sites that fully overwrite the buffer before reading (the
    /// FWD/BWI row load copies every element), skipping [`Scratch::acc`]'s
    /// zero-fill memset on the hot path.
    #[inline]
    pub fn acc_uninit(&mut self, n: usize) -> &mut [f32] {
        if self.buf.len() < n {
            self.buf.resize(n, 0.0);
        }
        &mut self.buf[..n]
    }
}

/// Micro-op accounting filled by every kernel. All memory counters are in
/// units of V-wide (64 B) vector accesses, which on the modeled machine is
/// one cache line.
///
/// Invariant: `popcount_hist` always has at least `V + 1` buckets — both
/// constructors and [`KernelStats::merge`] guarantee it, so the hot-path
/// [`KernelStats::record_check`] indexes without a re-init branch.
#[derive(Debug, Clone, PartialEq)]
pub struct KernelStats {
    /// V-wide FMAs actually executed.
    pub fma_vec: u64,
    /// V-wide FMAs skipped thanks to detected zeros.
    pub fma_vec_skipped: u64,
    /// Vector compares against zero (one per input vector inspected).
    pub zero_checks: u64,
    /// Histogram over the zero-check mask popcount (0..=V). Drives both the
    /// Algorithm-3 loop-iteration count and the branch-mispredict model.
    pub popcount_hist: Vec<u64>,
    /// V-wide loads of input (D or ∂L/∂Y being scanned).
    pub loads_in: u64,
    /// V-wide loads of filter operands.
    pub loads_flt: u64,
    /// V-wide loads of the output/accumulator working set.
    pub loads_out: u64,
    /// V-wide stores of the output/accumulator working set.
    pub stores_out: u64,
    /// Cheap integer ops in the skip machinery (Alg. 3: ~8 per set lane).
    pub int_ops: u64,
    /// Row sweeps executed.
    pub sweeps: u64,
    /// Non-FMA vector floating-point ops (transforms, reductions, max).
    pub vec_fp_ops: u64,
    /// Bytes of filter working set touched per sweep (L1 residency check).
    pub filter_bytes_per_sweep: u64,
}

impl Default for KernelStats {
    /// Zeroed counters with the histogram invariant already established
    /// (`V + 1` buckets), so a `Default`-constructed block records checks
    /// without any lazy re-initialization.
    fn default() -> KernelStats {
        KernelStats {
            fma_vec: 0,
            fma_vec_skipped: 0,
            zero_checks: 0,
            popcount_hist: vec![0; V + 1],
            loads_in: 0,
            loads_flt: 0,
            loads_out: 0,
            stores_out: 0,
            int_ops: 0,
            sweeps: 0,
            vec_fp_ops: 0,
            filter_bytes_per_sweep: 0,
        }
    }
}

impl KernelStats {
    pub fn new() -> KernelStats {
        KernelStats::default()
    }

    /// Record one zero-check over a V-lane mask with `nonzeros` set lanes.
    /// Hot path: a plain increment — the `V + 1`-bucket histogram invariant
    /// is guaranteed by the constructors and [`KernelStats::merge`], so no
    /// emptiness branch runs per check.
    #[inline]
    pub fn record_check(&mut self, nonzeros: usize) {
        self.zero_checks += 1;
        self.popcount_hist[nonzeros] += 1;
    }

    /// Total FMAs had nothing been skipped.
    pub fn fma_total(&self) -> u64 {
        self.fma_vec + self.fma_vec_skipped
    }

    /// Fraction of FMAs skipped.
    pub fn skip_fraction(&self) -> f64 {
        let t = self.fma_total();
        if t == 0 {
            0.0
        } else {
            self.fma_vec_skipped as f64 / t as f64
        }
    }

    /// Merge another stats block (for multi-sweep / multi-thread merges).
    pub fn merge(&mut self, other: &KernelStats) {
        self.fma_vec += other.fma_vec;
        self.fma_vec_skipped += other.fma_vec_skipped;
        self.zero_checks += other.zero_checks;
        if self.popcount_hist.len() < other.popcount_hist.len() {
            self.popcount_hist.resize(other.popcount_hist.len(), 0);
        }
        for (a, b) in self.popcount_hist.iter_mut().zip(&other.popcount_hist) {
            *a += b;
        }
        self.loads_in += other.loads_in;
        self.loads_flt += other.loads_flt;
        self.loads_out += other.loads_out;
        self.stores_out += other.stores_out;
        self.int_ops += other.int_ops;
        self.sweeps += other.sweeps;
        self.vec_fp_ops += other.vec_fp_ops;
        self.filter_bytes_per_sweep = self.filter_bytes_per_sweep.max(other.filter_bytes_per_sweep);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn out_dims_match_table2_examples() {
        // vgg3_2: 256ch 56x56 3x3 s1 → 56x56
        let c = ConvConfig::square(16, 256, 256, 56, 3, 1);
        assert_eq!((c.out_h(), c.out_w()), (56, 56));
        // resnet3_2/r: 128ch 56x56 3x3 s2 → 28x28
        let c = ConvConfig::square(16, 128, 128, 56, 3, 2);
        assert_eq!((c.out_h(), c.out_w()), (28, 28));
        // resnet2_1a: 1x1 s1 → same
        let c = ConvConfig::square(16, 64, 64, 56, 1, 1);
        assert_eq!((c.out_h(), c.out_w()), (56, 56));
    }

    #[test]
    fn flops_counts() {
        let c = ConvConfig::square(1, 16, 16, 4, 1, 1);
        // 1*16*4*4*16*1*1 MACs * 2
        assert_eq!(c.fwd_flops(), 2 * 16 * 16 * 16);
        assert_eq!(c.fwd_vec_fmas(), 16 * 16); // K/V=1
    }

    #[test]
    fn validate_catches_bad_configs() {
        let mut c = ConvConfig::square(1, 16, 16, 4, 3, 1);
        assert!(c.validate().is_ok());
        c.c = 17;
        assert!(c.validate().is_err());
        let mut c2 = ConvConfig::square(1, 16, 16, 4, 3, 1);
        c2.pad_h = 0;
        c2.pad_w = 0;
        c2.h = 2;
        c2.w = 2;
        assert!(c2.validate().is_err());
    }

    #[test]
    fn default_stats_record_without_reinit() {
        // The histogram invariant must hold for *both* constructors — the
        // old lazy re-init branch in record_check is gone.
        for mut st in [KernelStats::default(), KernelStats::new()] {
            assert_eq!(st.popcount_hist.len(), V + 1);
            st.record_check(0);
            st.record_check(V);
            assert_eq!(st.zero_checks, 2);
            assert_eq!(st.popcount_hist[0], 1);
            assert_eq!(st.popcount_hist[V], 1);
        }
    }

    #[test]
    fn merge_preserves_hist_invariant() {
        let mut a = KernelStats::default();
        let mut b = KernelStats::new();
        b.record_check(7);
        a.merge(&b);
        assert!(a.popcount_hist.len() >= V + 1);
        a.record_check(V); // must not panic after a merge
        assert_eq!(a.popcount_hist[7], 1);
    }

    #[test]
    fn scratch_reuses_allocation_and_zeroes() {
        let mut s = Scratch::new();
        {
            let acc = s.acc(64);
            assert_eq!(acc.len(), 64);
            assert!(acc.iter().all(|&v| v == 0.0));
            acc.iter_mut().for_each(|v| *v = 7.0);
        }
        let ptr = s.acc(64).as_ptr();
        // same length again: same allocation, contents re-zeroed
        let acc = s.acc(64);
        assert_eq!(acc.as_ptr(), ptr);
        assert!(acc.iter().all(|&v| v == 0.0));
        // shrinking must not leave stale tail values visible
        assert!(s.acc(16).iter().all(|&v| v == 0.0));
    }

    #[test]
    fn scratch_uninit_has_right_length_and_reuses() {
        let mut s = Scratch::new();
        s.acc(32).iter_mut().for_each(|v| *v = 3.0);
        // acc_uninit makes no content promise — only length and reuse
        let b = s.acc_uninit(16);
        assert_eq!(b.len(), 16);
        let ptr = s.acc_uninit(32).as_ptr();
        assert_eq!(s.acc_uninit(32).as_ptr(), ptr);
        assert_eq!(s.acc_uninit(64).len(), 64);
    }

    #[test]
    fn stats_merge_and_skip_fraction() {
        let mut a = KernelStats::new();
        a.fma_vec = 60;
        a.fma_vec_skipped = 40;
        a.record_check(3);
        let mut b = KernelStats::new();
        b.fma_vec = 40;
        b.fma_vec_skipped = 60;
        b.record_check(3);
        b.record_check(16);
        a.merge(&b);
        assert_eq!(a.fma_total(), 200);
        assert_eq!(a.skip_fraction(), 0.5);
        assert_eq!(a.popcount_hist[3], 2);
        assert_eq!(a.popcount_hist[16], 1);
        assert_eq!(a.zero_checks, 3);
    }
}
