//! The `im2col` baseline: lower the convolution to a matrix multiplication.
//!
//! Flattens and duplicates input patches into a column matrix, then calls a
//! blocked GEMM (§5.1: "creating the matrices incurs time and memory
//! overheads, so this implementation is generally slower than direct").
//! The cost accounting charges both the lowering traffic and the GEMM.

use super::{ConvConfig, KernelStats};
use crate::tensor::{ActTensor, FilterTensor};
use crate::V;

// The blocked GEMM itself was promoted into `kernels::gemm` (ISSUE 6) so
// the op router can share it; re-exported here for the existing callers.
pub use super::gemm::gemm;

/// GEMM cost accounting (dense): `m·k·n` MACs vectorized over `n`.
pub fn gemm_stats(m: usize, n: usize, k: usize, stats: &mut KernelStats) {
    let fma = (m as u64) * (k as u64) * (n as u64).div_ceil(V as u64);
    stats.fma_vec += fma;
    // b-row operand streamed from memory per (i, p); c row kept hot per i.
    stats.loads_flt += fma; // memory operand of each FMA
    stats.loads_out += (m as u64) * (n as u64).div_ceil(V as u64);
    stats.stores_out += (m as u64) * (n as u64).div_ceil(V as u64);
}

/// Build the column matrix: `col[(c·S+s)·R+r][ (i·OH+oy)·OW+ox ]`.
pub fn lower(cfg: &ConvConfig, d: &ActTensor) -> Vec<f32> {
    let (oh, ow) = (cfg.out_h(), cfg.out_w());
    let rows = cfg.c * cfg.s * cfg.r;
    let cols = cfg.n * oh * ow;
    let mut col = vec![0.0f32; rows * cols];
    for c in 0..cfg.c {
        for s in 0..cfg.s {
            for r in 0..cfg.r {
                let row = (c * cfg.s + s) * cfg.r + r;
                for i in 0..cfg.n {
                    for oy in 0..oh {
                        let iy = (oy * cfg.stride_p + s) as isize - cfg.pad_h as isize;
                        if iy < 0 || iy >= cfg.h as isize {
                            continue;
                        }
                        for ox in 0..ow {
                            let ix = (ox * cfg.stride_o + r) as isize - cfg.pad_w as isize;
                            if ix < 0 || ix >= cfg.w as isize {
                                continue;
                            }
                            col[row * cols + (i * oh + oy) * ow + ox] =
                                d.get(i, c, iy as usize, ix as usize);
                        }
                    }
                }
            }
        }
    }
    col
}

/// im2col forward convolution: lower + GEMM + write back to NCHWc.
pub fn fwd(
    cfg: &ConvConfig,
    d: &ActTensor,
    g: &FilterTensor,
    y: &mut ActTensor,
    stats: &mut KernelStats,
) {
    cfg.validate().expect("invalid conv config");
    let (oh, ow) = (cfg.out_h(), cfg.out_w());
    let rows = cfg.c * cfg.s * cfg.r;
    let cols = cfg.n * oh * ow;

    let col = lower(cfg, d);
    // a = G as [K][C·S·R]
    let gk = g.to_kcsr();
    let mut out = vec![0.0f32; cfg.k * cols];
    gemm(cfg.k, cols, rows, &gk, &col, &mut out);
    for i in 0..cfg.n {
        for k in 0..cfg.k {
            for oy in 0..oh {
                for ox in 0..ow {
                    y.set(i, k, oy, ox, out[k * cols + (i * oh + oy) * ow + ox]);
                }
            }
        }
    }
    stats_only(cfg, stats);
}

/// Data-independent cost accounting for the im2col path.
pub fn stats_only(cfg: &ConvConfig, stats: &mut KernelStats) {
    let (oh, ow) = (cfg.out_h(), cfg.out_w());
    let rows = (cfg.c * cfg.s * cfg.r) as u64;
    let cols = (cfg.n * oh * ow) as u64;
    // Lowering: read every input element S·R/ (stride²) times, write the
    // col matrix once. In vector units:
    let col_vecs = rows * cols / V as u64;
    stats.loads_in += col_vecs;
    stats.stores_out += col_vecs; // col write
    stats.loads_out += col_vecs; // col re-read by GEMM rhs panel streams
    gemm_stats(cfg.k, cols as usize, rows as usize, stats);
    // write-back of the output matrix into the tiled layout
    let out_vecs = (cfg.k as u64) * cols / V as u64;
    stats.loads_in += out_vecs;
    stats.stores_out += out_vecs;
    stats.sweeps += 1;
}

#[cfg(test)]
mod tests {
    use super::super::reference;
    use super::*;
    use crate::tensor::allclose;
    use crate::util::prng::Xorshift;

    #[test]
    fn gemm_matches_naive() {
        let (m, n, k) = (7, 33, 19);
        let mut rng = Xorshift::new(3);
        let a: Vec<f32> = (0..m * k).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let mut c = vec![0.0f32; m * n];
        gemm(m, n, k, &a, &b, &mut c);
        let mut cref = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                for p in 0..k {
                    cref[i * n + j] += a[i * k + p] * b[p * n + j];
                }
            }
        }
        assert!(allclose(&c, &cref, 1e-4, 1e-5));
    }

    #[test]
    fn fwd_matches_reference() {
        for (rs, stride) in [(3, 1), (3, 2), (1, 1)] {
            let cfg = ConvConfig::square(2, 32, 32, 8, rs, stride);
            let mut rng = Xorshift::new(9);
            let mut d = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
            d.fill_uniform(&mut rng, -1.0, 1.0);
            let mut g = FilterTensor::zeros(cfg.k, cfg.c, cfg.s, cfg.r);
            g.fill_uniform(&mut rng, -0.5, 0.5);
            let mut y = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
            let mut st = KernelStats::new();
            fwd(&cfg, &d, &g, &mut y, &mut st);
            let yref = reference::conv_fwd(&cfg, &d.to_nchw(), &g.to_kcsr());
            assert!(allclose(&y.to_nchw(), &yref, 1e-4, 1e-5), "rs={rs} stride={stride}");
            assert!(st.fma_vec > 0);
        }
    }

    #[test]
    fn stats_charge_lowering_traffic() {
        // im2col must move strictly more memory than the dense direct path.
        let cfg = ConvConfig::square(2, 32, 32, 8, 3, 1);
        let mut st_i2c = KernelStats::new();
        stats_only(&cfg, &mut st_i2c);
        let col_vecs =
            (cfg.c * cfg.s * cfg.r * cfg.n * cfg.out_h() * cfg.out_w() / crate::V) as u64;
        // the col matrix is written once and re-read by the GEMM
        assert!(st_i2c.stores_out >= col_vecs, "lowering write not charged");
        assert!(st_i2c.loads_out >= col_vecs, "lowering re-read not charged");
        // same MAC count as dense direct
        assert_eq!(st_i2c.fma_vec, cfg.fwd_vec_fmas());
    }
}
