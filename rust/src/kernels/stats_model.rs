//! Fast accounting-only runs: produce the exact [`KernelStats`] a
//! functional kernel run would produce, in O(input-size) time instead of
//! O(FLOPs).
//!
//! The paper's evaluation layers at batch 16 reach 10¹⁰–10¹¹ MACs; the
//! functional Rust kernels are for correctness and host-mode micro-
//! benchmarks on scaled-down configs, while the figure/table harnesses run
//! these accounting models over *full-size* inputs (the zero pattern is
//! still read element-by-element — sparsity statistics are exact) and feed
//! the Skylake-X model in [`crate::sim`].
//!
//! Consistency between the two paths is enforced by tests that run both on
//! small configurations and require identical counters.

use super::direct::SweepGeom;
use super::regalloc::{plan_bww, plan_fwd};
use super::{ConvConfig, KernelStats, SkipMode};
use crate::tensor::{ActTensor, BatchTiledTensor};
use crate::V;

/// Count, for every input row index, how many (oy, s) sweep pairs read it.
fn row_uses(cfg: &ConvConfig) -> Vec<u64> {
    let mut uses = vec![0u64; cfg.h];
    for oy in 0..cfg.out_h() {
        for s in 0..cfg.s {
            let iy = oy as isize * cfg.stride_p as isize + s as isize - cfg.pad_h as isize;
            if iy >= 0 && iy < cfg.h as isize {
                uses[iy as usize] += 1;
            }
        }
    }
    uses
}

/// Per-lane-nonzero counts of a V-vector.
#[inline(always)]
fn popcount(vec: &[f32]) -> usize {
    vec.iter().filter(|&&v| v != 0.0).count()
}

fn int_ops_for(mode: SkipMode, nonzeros: usize) -> u64 {
    match mode {
        SkipMode::Dense => 0,
        SkipMode::PerLaneBranch => V as u64,
        SkipMode::MaskLoop => 2 + 8 * nonzeros as u64,
    }
}

/// Accounting model of [`super::sparse_fwd::fwd`].
pub fn sparse_fwd_stats(cfg: &ConvConfig, d: &ActTensor, mode: SkipMode) -> KernelStats {
    let mut st = KernelStats::new();
    let plan = plan_fwd(cfg.k, cfg.r);
    let qv = (plan.q / V) as u64;
    let kq_count = (cfg.k / plan.q) as u64;
    let geom = SweepGeom::fwd(cfg);
    let taps_len: Vec<u64> = geom.taps.iter().map(|t| t.len() as u64).collect();
    let uses = row_uses(cfg);
    let (oh, ow) = (cfg.out_h(), cfg.out_w());

    for i in 0..cfg.n {
        for cb in 0..cfg.c / V {
            for iy in 0..cfg.h {
                let u = uses[iy] * kq_count;
                if u == 0 {
                    continue;
                }
                st.sweeps += u;
                st.loads_in += u * cfg.w as u64;
                for x in 0..cfg.w {
                    if taps_len[x] == 0 {
                        continue;
                    }
                    let nz = popcount(d.vec(i, cb, iy, x));
                    st.zero_checks += u;
                    st.popcount_hist[nz] += u;
                    let t_here = taps_len[x] * qv;
                    match mode {
                        SkipMode::Dense => st.fma_vec += (V as u64) * t_here * u,
                        _ => {
                            st.fma_vec += nz as u64 * t_here * u;
                            st.fma_vec_skipped += (V - nz) as u64 * t_here * u;
                        }
                    }
                    st.int_ops += int_ops_for(mode, nz) * u;
                }
            }
        }
    }
    let tasks = (cfg.n * oh) as u64 * kq_count;
    st.loads_out += tasks * (ow as u64) * qv;
    st.stores_out += tasks * (ow as u64) * qv;
    st.filter_bytes_per_sweep = (cfg.s * cfg.r * plan.q * V * 4) as u64;
    st
}

/// Accounting model of the dense [`super::direct::fwd`] baseline.
pub fn direct_fwd_stats(cfg: &ConvConfig) -> KernelStats {
    let mut st = KernelStats::new();
    let plan = plan_fwd(cfg.k, cfg.r);
    let qv = (plan.q / V) as u64;
    let kq_count = (cfg.k / plan.q) as u64;
    let geom = SweepGeom::fwd(cfg);
    let taps_total = geom.total_taps() as u64;
    let uses = row_uses(cfg);
    let sweeps: u64 =
        uses.iter().sum::<u64>() * (cfg.n as u64) * (cfg.c as u64 / V as u64) * kq_count;
    // FMA count: valid (oy,s,x,tap) combinations; per input row the taps
    // sum is geometry-only.
    let mut fma = 0u64;
    for iy in 0..cfg.h {
        fma += uses[iy] * taps_total;
    }
    st.fma_vec = fma * (cfg.n as u64) * (cfg.c as u64) * kq_count * qv;
    st.sweeps = sweeps;
    st.loads_in = sweeps * cfg.w as u64;
    let tasks = (cfg.n * cfg.out_h()) as u64 * kq_count;
    st.loads_out = tasks * cfg.out_w() as u64 * qv;
    st.stores_out = st.loads_out;
    st.filter_bytes_per_sweep = (cfg.r * plan.q * V * 4) as u64;
    st
}

/// Accounting model of [`super::sparse_bwi::bwi`] (scans ∂L/∂Y).
pub fn sparse_bwi_stats(cfg: &ConvConfig, dy: &ActTensor, mode: SkipMode) -> KernelStats {
    let mut st = KernelStats::new();
    let plan = plan_fwd(cfg.c, cfg.r);
    let qv = (plan.q / V) as u64;
    let cq_count = (cfg.c / plan.q) as u64;
    let (oh, ow) = (cfg.out_h(), cfg.out_w());

    // How many (y, s) pairs sweep each output row oy.
    let mut oy_uses = vec![0u64; oh];
    for y in 0..cfg.h {
        for s in 0..cfg.s {
            let t = y as isize + cfg.pad_h as isize - s as isize;
            if t >= 0 && t % cfg.stride_p as isize == 0 {
                let oy = (t / cfg.stride_p as isize) as usize;
                if oy < oh {
                    oy_uses[oy] += 1;
                }
            }
        }
    }
    // Column taps are s-independent: ox → valid r count.
    let taps_len: Vec<u64> = (0..ow)
        .map(|ox| {
            (0..cfg.r)
                .filter(|&r| {
                    let x =
                        ox as isize * cfg.stride_o as isize + r as isize - cfg.pad_w as isize;
                    x >= 0 && x < cfg.w as isize
                })
                .count() as u64
        })
        .collect();

    for i in 0..cfg.n {
        for kb in 0..cfg.k / V {
            for oy in 0..oh {
                let u = oy_uses[oy] * cq_count;
                if u == 0 {
                    continue;
                }
                st.sweeps += u;
                st.loads_in += u * ow as u64;
                for ox in 0..ow {
                    if taps_len[ox] == 0 {
                        continue;
                    }
                    let nz = popcount(dy.vec(i, kb, oy, ox));
                    st.zero_checks += u;
                    st.popcount_hist[nz] += u;
                    let t_here = taps_len[ox] * qv;
                    match mode {
                        SkipMode::Dense => st.fma_vec += (V as u64) * t_here * u,
                        _ => {
                            st.fma_vec += nz as u64 * t_here * u;
                            st.fma_vec_skipped += (V - nz) as u64 * t_here * u;
                        }
                    }
                    st.int_ops += int_ops_for(mode, nz) * u;
                }
            }
        }
    }
    let tasks = (cfg.n * cfg.h) as u64 * cq_count;
    st.loads_out += tasks * cfg.w as u64 * qv;
    st.stores_out += tasks * cfg.w as u64 * qv;
    st.filter_bytes_per_sweep = (cfg.s * cfg.r * plan.q * V * 4) as u64;
    st
}

/// Accounting model of the dense direct BWI baseline.
pub fn direct_bwi_stats(cfg: &ConvConfig) -> KernelStats {
    let mut st = KernelStats::new();
    let plan = plan_fwd(cfg.c, cfg.r);
    let qv = (plan.q / V) as u64;
    let cq_count = (cfg.c / plan.q) as u64;
    let (oh, ow) = (cfg.out_h(), cfg.out_w());
    let mut valid_rows = 0u64;
    for oy in 0..oh {
        for s in 0..cfg.s {
            let iy = oy as isize * cfg.stride_p as isize + s as isize - cfg.pad_h as isize;
            if iy >= 0 && iy < cfg.h as isize {
                valid_rows += 1;
            }
        }
    }
    let mut taps_total = 0u64;
    for ox in 0..ow {
        for r in 0..cfg.r {
            let ix = ox as isize * cfg.stride_o as isize + r as isize - cfg.pad_w as isize;
            if ix >= 0 && ix < cfg.w as isize {
                taps_total += 1;
            }
        }
    }
    let sweeps = (cfg.n as u64) * valid_rows * cq_count * (cfg.k as u64 / V as u64);
    st.sweeps = sweeps;
    st.loads_in = sweeps * ow as u64;
    st.fma_vec = sweeps * taps_total * V as u64 * qv;
    st.loads_out = (cfg.n * cfg.h) as u64 * cq_count * cfg.w as u64 * qv;
    st.stores_out = st.loads_out;
    st.filter_bytes_per_sweep = (cfg.r * plan.q * V * 4) as u64;
    st
}

/// Accounting model of [`super::sparse_bww::bww`] (scans D, N-vectorized;
/// one check per input column per sweep — Algorithm 5, line 7).
pub fn sparse_bww_stats(cfg: &ConvConfig, d: &BatchTiledTensor, mode: SkipMode) -> KernelStats {
    let mut st = KernelStats::new();
    let plan = plan_bww(cfg.k, cfg.r);
    let qv = (plan.q / V) as u64;
    let kq_count = (cfg.k / plan.q) as u64;

    let uses = row_uses(cfg); // (oy, s) pairs reading each input row
    // taps per input column: number of (ox, r) pairs hitting ix
    let taps = super::sparse_bww::bww_col_taps(cfg);
    let taps_len: Vec<u64> = taps.iter().map(|t| t.len() as u64).collect();

    for nb in 0..cfg.n / V {
        for c in 0..cfg.c {
            for iy in 0..cfg.h {
                let u = uses[iy] * kq_count;
                if u == 0 {
                    continue;
                }
                st.sweeps += u; // sweeps at (nb, oy, s, qb, c) granularity
                for ix in 0..cfg.w {
                    if taps_len[ix] == 0 {
                        continue;
                    }
                    let nz = popcount(d.vec(nb, c, iy, ix));
                    st.zero_checks += u;
                    st.popcount_hist[nz] += u;
                    st.loads_in += u;
                    let t_here = taps_len[ix] * qv;
                    match mode {
                        SkipMode::Dense => st.fma_vec += (V as u64) * t_here * u,
                        _ => {
                            st.fma_vec += nz as u64 * t_here * u;
                            st.fma_vec_skipped += (V - nz) as u64 * t_here * u;
                        }
                    }
                    st.int_ops += int_ops_for(mode, nz) * u;
                }
            }
        }
    }
    st.loads_out = st.sweeps * (cfg.r as u64) * qv;
    st.stores_out = st.loads_out;
    st.filter_bytes_per_sweep = (cfg.r * plan.q * 4) as u64;
    st
}

/// Accounting model of the dense direct BWW baseline.
pub fn direct_bww_stats(cfg: &ConvConfig) -> KernelStats {
    let mut st = KernelStats::new();
    let plan = plan_bww(cfg.k, cfg.r);
    let qv = (plan.q / V) as u64;
    let kq_count = (cfg.k / plan.q) as u64;
    let ow = cfg.out_w();
    let uses = row_uses(cfg);
    let sweeps: u64 =
        uses.iter().sum::<u64>() * (cfg.n as u64 / V as u64) * kq_count * cfg.c as u64;
    let mut taps_total = 0u64;
    for ox in 0..ow {
        for r in 0..cfg.r {
            let ix = ox as isize * cfg.stride_o as isize + r as isize - cfg.pad_w as isize;
            if ix >= 0 && ix < cfg.w as isize {
                taps_total += 1;
            }
        }
    }
    st.sweeps = sweeps;
    st.fma_vec = sweeps * taps_total * V as u64 * qv;
    st.loads_in = sweeps * taps_total;
    st.loads_out = sweeps * cfg.r as u64 * qv;
    st.stores_out = st.loads_out;
    st.filter_bytes_per_sweep = (cfg.r * plan.q * 4) as u64;
    st
}

// ---------------------------------------------------------------------------
// Expected-value (i.i.d.) variants: identical accounting in expectation for
// Bernoulli zero patterns, O(geometry) instead of O(input) — used by the
// selector and the Fig-4/Table-6 projections where patterns are synthetic
// anyway. Scanned variants above remain the path for *real* profiled
// patterns (the end-to-end trainer).
// ---------------------------------------------------------------------------

/// Binomial(V, 1−s) pmf scaled to `total` checks (rounded to counts).
fn binom_hist(total: u64, sparsity: f64) -> Vec<u64> {
    let p = (1.0 - sparsity).clamp(0.0, 1.0);
    let mut hist = vec![0u64; V + 1];
    if total == 0 {
        return hist;
    }
    // pmf via log to stay stable at the tails
    for (k, h) in hist.iter_mut().enumerate() {
        let mut logc = 0.0f64;
        for i in 0..k {
            logc += ((V - i) as f64 / (i + 1) as f64).ln();
        }
        let logp = if p <= 0.0 {
            if k == 0 {
                0.0
            } else {
                f64::NEG_INFINITY
            }
        } else if p >= 1.0 {
            if k == V {
                0.0
            } else {
                f64::NEG_INFINITY
            }
        } else {
            logc + k as f64 * p.ln() + (V - k) as f64 * (1.0 - p).ln()
        };
        *h = (logp.exp() * total as f64).round() as u64;
    }
    hist
}

/// Shared i.i.d. expectation fill: given per-check structure, scale by the
/// expected nonzero lanes `E[nz] = V·(1−s)`.
fn fill_iid(
    st: &mut KernelStats,
    total_checks: u64,
    weighted_taps_qv: f64, // Σ over checks of taps·qv (FMA groups per lane)
    sparsity: f64,
    mode: SkipMode,
) {
    let e_nz = V as f64 * (1.0 - sparsity);
    st.zero_checks = total_checks;
    st.popcount_hist = binom_hist(total_checks, sparsity);
    match mode {
        SkipMode::Dense => {
            st.fma_vec = (V as f64 * weighted_taps_qv).round() as u64;
            st.fma_vec_skipped = 0;
        }
        _ => {
            st.fma_vec = (e_nz * weighted_taps_qv).round() as u64;
            st.fma_vec_skipped = ((V as f64 - e_nz) * weighted_taps_qv).round() as u64;
        }
    }
    st.int_ops = match mode {
        SkipMode::Dense => 0,
        SkipMode::PerLaneBranch => total_checks * V as u64,
        SkipMode::MaskLoop => ((2.0 + 8.0 * e_nz) * total_checks as f64).round() as u64,
    };
}

/// Expected SparseTrain FWD stats over an i.i.d. pattern of `sparsity`.
pub fn sparse_fwd_stats_iid(cfg: &ConvConfig, sparsity: f64, mode: SkipMode) -> KernelStats {
    let mut st = KernelStats::new();
    let plan = plan_fwd(cfg.k, cfg.r);
    let qv = (plan.q / V) as u64;
    let kq_count = (cfg.k / plan.q) as u64;
    let geom = SweepGeom::fwd(cfg);
    let uses = row_uses(cfg);
    let reps = (cfg.n as u64) * (cfg.c as u64 / V as u64); // images × c-tiles
    let uses_total: u64 = uses.iter().sum::<u64>() * kq_count;
    let checked_cols = geom.taps.iter().filter(|t| !t.is_empty()).count() as u64;
    let total_checks = reps * uses_total * checked_cols;
    let wt: f64 = geom.taps.iter().map(|t| t.len() as f64).sum::<f64>()
        * qv as f64
        * (reps * uses_total) as f64;
    fill_iid(&mut st, total_checks, wt, sparsity, mode);
    st.sweeps = reps * uses_total;
    st.loads_in = st.sweeps * cfg.w as u64;
    let tasks = (cfg.n * cfg.out_h()) as u64 * kq_count;
    st.loads_out = tasks * cfg.out_w() as u64 * qv;
    st.stores_out = st.loads_out;
    st.filter_bytes_per_sweep = (cfg.s * cfg.r * plan.q * V * 4) as u64;
    st
}

/// Expected SparseTrain BWI stats over an i.i.d. ∂L/∂Y pattern.
pub fn sparse_bwi_stats_iid(cfg: &ConvConfig, sparsity: f64, mode: SkipMode) -> KernelStats {
    let mut st = KernelStats::new();
    let plan = plan_fwd(cfg.c, cfg.r);
    let qv = (plan.q / V) as u64;
    let cq_count = (cfg.c / plan.q) as u64;
    let (oh, ow) = (cfg.out_h(), cfg.out_w());
    let mut oy_uses = vec![0u64; oh];
    for y in 0..cfg.h {
        for s in 0..cfg.s {
            let t = y as isize + cfg.pad_h as isize - s as isize;
            if t >= 0 && t % cfg.stride_p as isize == 0 {
                let oy = (t / cfg.stride_p as isize) as usize;
                if oy < oh {
                    oy_uses[oy] += 1;
                }
            }
        }
    }
    let taps_len: Vec<u64> = (0..ow)
        .map(|ox| {
            (0..cfg.r)
                .filter(|&r| {
                    let x = ox as isize * cfg.stride_o as isize + r as isize - cfg.pad_w as isize;
                    x >= 0 && x < cfg.w as isize
                })
                .count() as u64
        })
        .collect();
    let reps = (cfg.n as u64) * (cfg.k as u64 / V as u64);
    let uses_total: u64 = oy_uses.iter().sum::<u64>() * cq_count;
    let checked_cols = taps_len.iter().filter(|&&t| t > 0).count() as u64;
    let total_checks = reps * uses_total * checked_cols;
    let wt: f64 =
        taps_len.iter().map(|&t| t as f64).sum::<f64>() * qv as f64 * (reps * uses_total) as f64;
    fill_iid(&mut st, total_checks, wt, sparsity, mode);
    st.sweeps = reps * uses_total;
    st.loads_in = st.sweeps * ow as u64;
    let tasks = (cfg.n * cfg.h) as u64 * cq_count;
    st.loads_out = tasks * cfg.w as u64 * qv;
    st.stores_out = st.loads_out;
    st.filter_bytes_per_sweep = (cfg.s * cfg.r * plan.q * V * 4) as u64;
    st
}

/// Expected SparseTrain BWW stats over an i.i.d. checked-operand pattern
/// (one check per input column per sweep).
pub fn sparse_bww_stats_iid(cfg: &ConvConfig, sparsity: f64, mode: SkipMode) -> KernelStats {
    let mut st = KernelStats::new();
    let plan = plan_bww(cfg.k, cfg.r);
    let qv = (plan.q / V) as u64;
    let kq_count = (cfg.k / plan.q) as u64;
    let uses = row_uses(cfg);
    let taps = super::sparse_bww::bww_col_taps(cfg);
    let taps_total: u64 = taps.iter().map(|t| t.len() as u64).sum();
    let checked_cols = taps.iter().filter(|t| !t.is_empty()).count() as u64;
    let sweeps: u64 =
        uses.iter().sum::<u64>() * (cfg.n as u64 / V as u64) * kq_count * cfg.c as u64;
    let total_checks = sweeps * checked_cols;
    let wt = (sweeps * taps_total * qv) as f64;
    fill_iid(&mut st, total_checks, wt, sparsity, mode);
    st.sweeps = sweeps;
    st.loads_in = total_checks;
    st.loads_out = sweeps * cfg.r as u64 * qv;
    st.stores_out = st.loads_out;
    st.filter_bytes_per_sweep = (cfg.r * plan.q * 4) as u64;
    st
}

#[cfg(test)]
mod tests {
    use super::super::{direct, sparse_bwi, sparse_bww, sparse_fwd};
    use super::*;
    use crate::tensor::FilterTensor;
    use crate::util::prng::Xorshift;

    fn assert_stats_eq(a: &KernelStats, b: &KernelStats, what: &str) {
        assert_eq!(a.fma_vec, b.fma_vec, "{what}: fma_vec");
        assert_eq!(a.fma_vec_skipped, b.fma_vec_skipped, "{what}: fma_vec_skipped");
        assert_eq!(a.zero_checks, b.zero_checks, "{what}: zero_checks");
        assert_eq!(a.popcount_hist, b.popcount_hist, "{what}: popcount_hist");
        assert_eq!(a.loads_in, b.loads_in, "{what}: loads_in");
        assert_eq!(a.loads_out, b.loads_out, "{what}: loads_out");
        assert_eq!(a.stores_out, b.stores_out, "{what}: stores_out");
        assert_eq!(a.int_ops, b.int_ops, "{what}: int_ops");
        assert_eq!(a.sweeps, b.sweeps, "{what}: sweeps");
    }

    fn configs() -> Vec<ConvConfig> {
        vec![
            ConvConfig::square(2, 32, 32, 8, 3, 1),
            ConvConfig::square(2, 32, 32, 9, 3, 2),
            ConvConfig::square(2, 32, 64, 7, 1, 1),
            ConvConfig::square(1, 32, 32, 9, 5, 1),
        ]
    }

    #[test]
    fn fwd_model_matches_functional() {
        for cfg in configs() {
            for mode in [SkipMode::MaskLoop, SkipMode::Dense, SkipMode::PerLaneBranch] {
                let mut rng = Xorshift::new(55);
                let mut d = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
                d.fill_relu_sparse(&mut rng, 0.6);
                let mut g = FilterTensor::zeros(cfg.k, cfg.c, cfg.s, cfg.r);
                g.fill_uniform(&mut rng, -0.5, 0.5);
                let mut y = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
                let mut st = KernelStats::new();
                sparse_fwd::fwd(&cfg, &d, &g, &mut y, mode, &mut st);
                let model = sparse_fwd_stats(&cfg, &d, mode);
                assert_stats_eq(&model, &st, &format!("fwd {cfg:?} {mode:?}"));
            }
        }
    }

    #[test]
    fn direct_fwd_model_matches_functional() {
        for cfg in configs() {
            let mut rng = Xorshift::new(56);
            let mut d = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
            d.fill_uniform(&mut rng, -1.0, 1.0);
            let mut g = FilterTensor::zeros(cfg.k, cfg.c, cfg.s, cfg.r);
            g.fill_uniform(&mut rng, -0.5, 0.5);
            let mut y = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
            let mut st = KernelStats::new();
            direct::fwd(&cfg, &d, &g, &mut y, &mut st);
            let model = direct_fwd_stats(&cfg);
            assert_eq!(model.fma_vec, st.fma_vec, "direct fwd fma {cfg:?}");
            assert_eq!(model.sweeps, st.sweeps, "direct fwd sweeps {cfg:?}");
            assert_eq!(model.loads_in, st.loads_in, "direct fwd loads_in {cfg:?}");
            assert_eq!(model.loads_out, st.loads_out, "direct fwd loads_out {cfg:?}");
        }
    }

    #[test]
    fn bwi_model_matches_functional() {
        for cfg in configs() {
            let mut rng = Xorshift::new(57);
            let mut dy = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
            dy.fill_relu_sparse(&mut rng, 0.5);
            let mut g = FilterTensor::zeros(cfg.k, cfg.c, cfg.s, cfg.r);
            g.fill_uniform(&mut rng, -0.5, 0.5);
            let gt = g.transpose_channels();
            let mut dd = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
            let mut st = KernelStats::new();
            sparse_bwi::bwi(&cfg, &dy, &gt, &mut dd, SkipMode::MaskLoop, &mut st);
            let model = sparse_bwi_stats(&cfg, &dy, SkipMode::MaskLoop);
            assert_stats_eq(&model, &st, &format!("bwi {cfg:?}"));
        }
    }

    #[test]
    fn bww_model_matches_functional() {
        for cfg in [
            ConvConfig::square(16, 32, 32, 6, 3, 1),
            ConvConfig::square(16, 32, 32, 8, 3, 2),
            ConvConfig::square(16, 32, 64, 5, 1, 1),
        ] {
            let mut rng = Xorshift::new(58);
            let mut dsrc = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
            dsrc.fill_relu_sparse(&mut rng, 0.55);
            let d = BatchTiledTensor::from_act(&dsrc);
            let mut dy = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
            dy.fill_uniform(&mut rng, -1.0, 1.0);
            let mut dg = FilterTensor::zeros(cfg.k, cfg.c, cfg.s, cfg.r);
            let mut st = KernelStats::new();
            sparse_bww::bww(&cfg, &d, &dy, &mut dg, SkipMode::MaskLoop, &mut st);
            let model = sparse_bww_stats(&cfg, &d, SkipMode::MaskLoop);
            assert_stats_eq(&model, &st, &format!("bww {cfg:?}"));
        }
    }

    #[test]
    fn iid_expectation_matches_scanned_random_pattern() {
        // The i.i.d. closed forms must agree with scanning an actual
        // Bernoulli pattern to within sampling noise.
        let cfg = ConvConfig::square(4, 64, 64, 12, 3, 1);
        let s = 0.6;
        let mut rng = Xorshift::new(91);
        let mut d = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
        d.fill_relu_sparse(&mut rng, s);
        let scanned = sparse_fwd_stats(&cfg, &d, SkipMode::MaskLoop);
        let iid = sparse_fwd_stats_iid(&cfg, s, SkipMode::MaskLoop);
        assert_eq!(iid.zero_checks, scanned.zero_checks);
        assert_eq!(iid.sweeps, scanned.sweeps);
        assert_eq!(iid.loads_out, scanned.loads_out);
        let rel = |a: u64, b: u64| (a as f64 - b as f64).abs() / b.max(1) as f64;
        assert!(rel(iid.fma_vec, scanned.fma_vec) < 0.03, "{iid:?} vs {scanned:?}");
        assert!(rel(iid.int_ops, scanned.int_ops) < 0.03);
        // BWI
        let mut dy = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
        dy.fill_relu_sparse(&mut rng, s);
        let scanned = sparse_bwi_stats(&cfg, &dy, SkipMode::MaskLoop);
        let iid = sparse_bwi_stats_iid(&cfg, s, SkipMode::MaskLoop);
        assert_eq!(iid.zero_checks, scanned.zero_checks);
        assert!(rel(iid.fma_vec, scanned.fma_vec) < 0.03);
        // BWW
        let cfgb = ConvConfig::square(16, 32, 32, 8, 3, 1);
        let mut db = ActTensor::zeros(cfgb.n, cfgb.c, cfgb.h, cfgb.w);
        db.fill_relu_sparse(&mut rng, s);
        let scanned = sparse_bww_stats(&cfgb, &BatchTiledTensor::from_act(&db), SkipMode::MaskLoop);
        let iid = sparse_bww_stats_iid(&cfgb, s, SkipMode::MaskLoop);
        assert_eq!(iid.zero_checks, scanned.zero_checks);
        assert!(rel(iid.fma_vec, scanned.fma_vec) < 0.04);
    }

    #[test]
    fn iid_dense_matches_direct_fma_count() {
        let cfg = ConvConfig::square(16, 256, 256, 28, 3, 1);
        let iid = sparse_fwd_stats_iid(&cfg, 0.0, SkipMode::MaskLoop);
        let direct = direct_fwd_stats(&cfg);
        assert_eq!(iid.fma_vec, direct.fma_vec);
        assert_eq!(iid.fma_vec_skipped, 0);
    }

    #[test]
    fn model_is_fast_on_paper_scale_layers() {
        // vgg4_2-sized accounting must run in well under a second.
        let cfg = ConvConfig::square(16, 512, 512, 28, 3, 1);
        let mut rng = Xorshift::new(60);
        let mut d = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
        d.fill_relu_sparse(&mut rng, 0.7);
        let t0 = std::time::Instant::now();
        let st = sparse_fwd_stats(&cfg, &d, SkipMode::MaskLoop);
        assert!(st.fma_total() > 1_000_000_000 / 16);
        assert!(t0.elapsed().as_secs_f64() < 2.0, "model too slow");
        assert!((st.skip_fraction() - 0.7).abs() < 0.02);
    }
}
