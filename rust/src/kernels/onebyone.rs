//! The specialized `1x1` baseline kernel (§5.2).
//!
//! For 1×1 layers the spatial reuse R×S is absent; MKL-DNN ships a
//! specialized algorithm that computes each output vector as a *reduction*
//! over input channels (output-stationary) instead of the input-stationary
//! accumulation of `direct`. The compute-to-memory ratio is ~9× lower than
//! a same-size 3×3 layer, so this kernel leans on streaming efficiency.

use super::{ConvConfig, KernelStats};
use crate::tensor::{ActTensor, FilterTensor};
use crate::V;

/// Whether the specialized kernel applies (1×1 filter).
pub fn applicable(cfg: &ConvConfig) -> bool {
    cfg.r == 1 && cfg.s == 1
}

/// Specialized 1×1 forward: `Y[i,k,·] = Σ_c D[i,c,·] · G[k,c]` as a
/// reduction, vectorized over K.
pub fn fwd(
    cfg: &ConvConfig,
    d: &ActTensor,
    g: &FilterTensor,
    y: &mut ActTensor,
    stats: &mut KernelStats,
) {
    assert!(applicable(cfg), "1x1 kernel requires R=S=1");
    cfg.validate().expect("invalid conv config");
    let (oh, ow) = (cfg.out_h(), cfg.out_w());
    let cb_count = cfg.c / V;
    let kb_count = cfg.k / V;

    for i in 0..cfg.n {
        for kb in 0..kb_count {
            for oy in 0..oh {
                let iy = oy * cfg.stride_p; // pad is 0 for 1x1 same-style
                for ox in 0..ow {
                    let ix = ox * cfg.stride_o;
                    let mut acc = [0.0f32; V];
                    for cb in 0..cb_count {
                        let dvec = d.vec(i, cb, iy, ix);
                        for cv in 0..V {
                            let dval = dvec[cv];
                            let gvec = g.vec(kb, cb, 0, 0, cv);
                            for l in 0..V {
                                acc[l] += dval * gvec[l];
                            }
                        }
                    }
                    y.vec_mut(i, kb, oy, ox).copy_from_slice(&acc);
                }
            }
        }
    }
    stats_only(cfg, stats);
}

/// Data-independent cost accounting for the reduction formulation.
pub fn stats_only(cfg: &ConvConfig, stats: &mut KernelStats) {
    let (oh, ow) = (cfg.out_h(), cfg.out_w());
    let outputs = (cfg.n * (cfg.k / V) * oh * ow) as u64;
    let fma = outputs * cfg.c as u64;
    stats.fma_vec += fma;
    stats.loads_flt += fma; // G operand from (cached) memory
    // each output vector: stored once, never reloaded (reduction);
    // each input vector: loaded once per K-tile pass
    stats.stores_out += outputs;
    // spatially-blocked: the input tile stays L1-resident across the
    // K-tile loop → each input vector is loaded once
    stats.loads_in += (cfg.n * (cfg.c / V) * oh * ow) as u64;
    stats.sweeps += (cfg.n * (cfg.k / V) * oh) as u64;
    stats.filter_bytes_per_sweep = stats.filter_bytes_per_sweep.max((cfg.c * V * 4) as u64);
}

#[cfg(test)]
mod tests {
    use super::super::reference;
    use super::*;
    use crate::tensor::allclose;
    use crate::util::prng::Xorshift;

    #[test]
    fn matches_reference() {
        for (c, k) in [(32, 64), (64, 32)] {
            let cfg = ConvConfig::square(2, c, k, 7, 1, 1);
            let mut rng = Xorshift::new(31);
            let mut d = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
            d.fill_uniform(&mut rng, -1.0, 1.0);
            let mut g = FilterTensor::zeros(cfg.k, cfg.c, 1, 1);
            g.fill_uniform(&mut rng, -0.5, 0.5);
            let mut y = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
            let mut st = KernelStats::new();
            fwd(&cfg, &d, &g, &mut y, &mut st);
            let yref = reference::conv_fwd(&cfg, &d.to_nchw(), &g.to_kcsr());
            assert!(allclose(&y.to_nchw(), &yref, 1e-4, 1e-5));
        }
    }

    #[test]
    fn strided_1x1_matches_reference() {
        // resnet downsample shortcuts use strided 1x1
        let mut cfg = ConvConfig::square(1, 32, 32, 8, 1, 2);
        cfg.pad_h = 0;
        cfg.pad_w = 0;
        let mut rng = Xorshift::new(33);
        let mut d = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
        d.fill_uniform(&mut rng, -1.0, 1.0);
        let mut g = FilterTensor::zeros(cfg.k, cfg.c, 1, 1);
        g.fill_uniform(&mut rng, -0.5, 0.5);
        let mut y = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
        let mut st = KernelStats::new();
        fwd(&cfg, &d, &g, &mut y, &mut st);
        let yref = reference::conv_fwd(&cfg, &d.to_nchw(), &g.to_kcsr());
        assert!(allclose(&y.to_nchw(), &yref, 1e-4, 1e-5));
    }

    #[test]
    fn reduction_stores_each_output_once() {
        let cfg = ConvConfig::square(2, 64, 64, 8, 1, 1);
        let mut st = KernelStats::new();
        stats_only(&cfg, &mut st);
        assert_eq!(st.stores_out, (cfg.n * (cfg.k / V) * cfg.out_h() * cfg.out_w()) as u64);
        assert_eq!(st.loads_out, 0);
    }
}
