//! Regenerates **Figure 3**: ReLU-output sparsity over 100-epoch training
//! of ResNet-34 / ResNet-50 / Fixup ResNet-50 (trajectory model — see
//! DESIGN.md §2 substitution 3; the measured counterpart comes from
//! `examples/end_to_end_train.rs`).
//!
//! The paper's observations, asserted here and visualized as a sampled
//! matrix: starts ≈50 %, rises rapidly then slowly decays, later layers
//! sparser, and a periodic residual-shortcut dip (strongest in ResNet-34
//! and Fixup ResNet-50).

use sparsetrain::bench::experiments::fig3;
use sparsetrain::util::stats::mean;
use sparsetrain::util::table::Table;

fn main() {
    let epochs = 100;
    for (net, matrix) in fig3(epochs) {
        let layers = matrix.len();
        let mut tab = Table::new(&format!(
            "Figure 3 ({}): sparsity by layer (rows sampled) and epoch",
            net.name()
        ))
        .header(&["layer", "e0", "e5", "e15", "e40", "e99", "mean"]);
        let sample_layers: Vec<usize> =
            [0, layers / 4, layers / 2, 3 * layers / 4, layers - 1].to_vec();
        for l in sample_layers {
            let row = &matrix[l];
            tab.row_strings(vec![
                format!("{l}"),
                format!("{:.2}", row[0]),
                format!("{:.2}", row[5]),
                format!("{:.2}", row[15]),
                format!("{:.2}", row[40]),
                format!("{:.2}", row[99]),
                format!("{:.2}", mean(row)),
            ]);
        }
        tab.print();

        // paper's qualitative claims, asserted
        let first_mean = mean(&matrix[1]);
        let last_mean = mean(&matrix[layers - 1]);
        assert!(
            last_mean > first_mean,
            "{}: later layers must be sparser ({first_mean:.2} vs {last_mean:.2})",
            net.name()
        );
        let l = layers / 2;
        assert!((matrix[l][0] - 0.5).abs() < 0.25, "{}: start ≈ 50%", net.name());
        let peak: f64 = (0..epochs).map(|e| matrix[l][e]).fold(0.0, f64::max);
        assert!(matrix[l][epochs - 1] <= peak, "{}: late decay", net.name());
        println!(
            "  {}: mid-layer epoch-0 {:.2} → peak {:.2} → final {:.2}\n",
            net.name(),
            matrix[l][0],
            peak,
            matrix[l][epochs - 1]
        );
    }
    println!("fig3 OK (trajectory assertions hold)");
}
