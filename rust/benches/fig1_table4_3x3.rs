//! Regenerates **Figure 1** and **Table 4**: speedup over `direct` on the
//! paper's 3×3 layers for FWD/BWI/BWW at 0–90 % sparsity, plus the
//! `im2col` and `winograd` baselines.
//!
//! Two modes:
//! * **model** — the analytical Skylake-X estimates over the full Table 2
//!   configurations at batch 16 (the paper's setup);
//! * **host** — real wallclock of the functional Rust kernels on a
//!   scaled-down 3×3 layer, verifying the *shape* (crossover, monotone
//!   speedup) on this machine, plus the row-sweep scheduler's parallel
//!   FWD/BWI/BWW speedup over the serial kernels.
//!
//! `cargo bench --bench fig1_table4_3x3 -- --threads 4` restricts both the
//! modeled machine and the host scheduler to 4 cores.

use sparsetrain::bench::experiments::{fig1_table4, machine_with_threads, SPARSITY_GRID};
use sparsetrain::bench::{black_box, BenchGroup};
use sparsetrain::coordinator::Scheduler;
use sparsetrain::kernels::{
    direct, sparse_bwi, sparse_bww, sparse_fwd, ConvConfig, KernelStats, SkipMode,
};
use sparsetrain::sim::Machine;
use sparsetrain::tensor::{ActTensor, BatchTiledTensor, FilterTensor};
use sparsetrain::util::cli::Args;
use sparsetrain::util::prng::Xorshift;
use sparsetrain::util::table::Table;

fn host_mode() {
    // Scaled 3×3 layer: N=1, C=K=64, 32×32 (full batch-16 layers would
    // take minutes per iteration in the functional kernels).
    let cfg = ConvConfig::square(1, 64, 64, 32, 3, 1);
    let mut rng = Xorshift::new(2024);
    let mut g = FilterTensor::zeros(cfg.k, cfg.c, cfg.s, cfg.r);
    g.fill_uniform(&mut rng, -0.5, 0.5);

    let mut group = BenchGroup::new("host: 3x3 C=K=64 32x32 N=1 (scaled)");
    group.start();

    let mut y = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
    let mut d_dense = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
    d_dense.fill_relu_sparse(&mut rng, 0.0);
    group.bench("direct (dense baseline)", || {
        y.fill_zero();
        let mut st = KernelStats::new();
        direct::fwd(&cfg, &d_dense, &g, &mut y, &mut st);
        black_box(&y);
    });

    let mut tab = Table::new("host-measured FWD speedup vs direct")
        .header(&["sparsity", "speedup", "skip frac"]);
    let base = group.ns_of("direct (dense baseline)").unwrap();
    for s in [0.0, 0.2, 0.4, 0.6, 0.8, 0.9] {
        let mut d = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
        d.fill_relu_sparse(&mut rng, s);
        let mut skip = 0.0;
        let r = group.bench(&format!("sparse fwd s={s:.1}"), || {
            y.fill_zero();
            let mut st = KernelStats::new();
            sparse_fwd::fwd(&cfg, &d, &g, &mut y, SkipMode::MaskLoop, &mut st);
            skip = st.skip_fraction();
            black_box(&y);
        });
        tab.row_strings(vec![
            format!("{:.0}%", s * 100.0),
            format!("{:.2}", base / r.ns()),
            format!("{skip:.2}"),
        ]);
    }
    tab.print();
}

/// Host-measured scaling of the row-sweep scheduler: serial kernel vs
/// `Scheduler::run_{fwd,bwi,bww}` at the given thread count, one row per
/// training component (§3.2.2 / §3.3 / §3.4).
fn host_parallel_mode(threads: usize) {
    // N=16 so BWW's minibatch vectorization applies; small spatial dims
    // keep the serial baselines quick.
    let cfg = ConvConfig::square(16, 32, 32, 16, 3, 1);
    let mut rng = Xorshift::new(4096);
    let mut d = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
    d.fill_relu_sparse(&mut rng, 0.5);
    let mut g = FilterTensor::zeros(cfg.k, cfg.c, cfg.s, cfg.r);
    g.fill_uniform(&mut rng, -0.5, 0.5);
    let gt = g.transpose_channels();
    let dt = BatchTiledTensor::from_act(&d);
    let mut dy = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
    dy.fill_relu_sparse(&mut rng, 0.5);

    let sched = Scheduler::new(threads);
    let mut group = BenchGroup::new(&format!(
        "host: scheduler scaling, {threads} threads (N=16 C=K=32 16x16)"
    ));
    group.start();

    let mut y = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
    group.bench("FWD serial", || {
        y.fill_zero();
        let mut st = KernelStats::new();
        sparse_fwd::fwd(&cfg, &d, &g, &mut y, SkipMode::MaskLoop, &mut st);
        black_box(&y);
    });
    group.bench("FWD scheduler", || {
        y.fill_zero();
        black_box(sched.run_fwd(&cfg, &d, &g, &mut y, SkipMode::MaskLoop).total_tasks);
    });

    let mut dd = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
    group.bench("BWI serial", || {
        dd.fill_zero();
        let mut st = KernelStats::new();
        sparse_bwi::bwi(&cfg, &dy, &gt, &mut dd, SkipMode::MaskLoop, &mut st);
        black_box(&dd);
    });
    group.bench("BWI scheduler", || {
        dd.fill_zero();
        black_box(sched.run_bwi(&cfg, &dy, &gt, &mut dd, SkipMode::MaskLoop).total_tasks);
    });

    let mut dg = FilterTensor::zeros(cfg.k, cfg.c, cfg.s, cfg.r);
    group.bench("BWW serial", || {
        dg.fill_zero();
        let mut st = KernelStats::new();
        sparse_bww::bww(&cfg, &dt, &dy, &mut dg, SkipMode::MaskLoop, &mut st);
        black_box(&dg);
    });
    group.bench("BWW scheduler", || {
        dg.fill_zero();
        black_box(sched.run_bww(&cfg, &dt, &dy, &mut dg, SkipMode::MaskLoop).total_tasks);
    });

    let mut tab = Table::new(&format!("scheduler speedup over serial at {threads} threads"))
        .header(&["comp", "speedup"]);
    for comp in ["FWD", "BWI", "BWW"] {
        let serial = group.ns_of(&format!("{comp} serial")).unwrap();
        let par = group.ns_of(&format!("{comp} scheduler")).unwrap();
        tab.row_strings(vec![comp.to_string(), format!("{:.2}", serial / par)]);
    }
    tab.print();
}

fn main() {
    // cargo appends `--bench` when invoking harness=false bench binaries;
    // accept and ignore it.
    let args = Args::from_env(&["threads"], &["bench"]).unwrap_or_else(|e| {
        eprintln!("error: {e}");
        std::process::exit(2);
    });
    let base = Machine::skylake_x();
    let threads = args.get_usize("threads", base.cores).unwrap_or_else(|e| {
        eprintln!("error: {e}");
        std::process::exit(2);
    });
    let m = machine_with_threads(&base, threads);
    println!("modeling {} active cores (--threads)", m.cores);
    println!("sparsity grid: {SPARSITY_GRID:?}");
    let (_rows, fig, tab) = fig1_table4(&m);
    fig.print();
    tab.print();
    host_mode();
    host_parallel_mode(threads);
}
