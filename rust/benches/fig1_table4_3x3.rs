//! Regenerates **Figure 1** and **Table 4**: speedup over `direct` on the
//! paper's 3×3 layers for FWD/BWI/BWW at 0–90 % sparsity, plus the
//! `im2col` and `winograd` baselines.
//!
//! Two modes:
//! * **model** — the analytical Skylake-X estimates over the full Table 2
//!   configurations at batch 16 (the paper's setup);
//! * **host** — real wallclock of the functional Rust kernels on a
//!   scaled-down 3×3 layer, verifying the *shape* (crossover, monotone
//!   speedup) on this machine.

use sparsetrain::bench::experiments::{fig1_table4, SPARSITY_GRID};
use sparsetrain::bench::{black_box, BenchGroup};
use sparsetrain::kernels::{direct, sparse_fwd, ConvConfig, KernelStats, SkipMode};
use sparsetrain::sim::Machine;
use sparsetrain::tensor::{ActTensor, FilterTensor};
use sparsetrain::util::prng::Xorshift;
use sparsetrain::util::table::Table;

fn host_mode() {
    // Scaled 3×3 layer: N=1, C=K=64, 32×32 (full batch-16 layers would
    // take minutes per iteration in the functional kernels).
    let cfg = ConvConfig::square(1, 64, 64, 32, 3, 1);
    let mut rng = Xorshift::new(2024);
    let mut g = FilterTensor::zeros(cfg.k, cfg.c, cfg.s, cfg.r);
    g.fill_uniform(&mut rng, -0.5, 0.5);

    let mut group = BenchGroup::new("host: 3x3 C=K=64 32x32 N=1 (scaled)");
    group.start();

    let mut y = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
    let mut d_dense = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
    d_dense.fill_relu_sparse(&mut rng, 0.0);
    group.bench("direct (dense baseline)", || {
        y.fill_zero();
        let mut st = KernelStats::new();
        direct::fwd(&cfg, &d_dense, &g, &mut y, &mut st);
        black_box(&y);
    });

    let mut tab = Table::new("host-measured FWD speedup vs direct")
        .header(&["sparsity", "speedup", "skip frac"]);
    let base = group.ns_of("direct (dense baseline)").unwrap();
    for s in [0.0, 0.2, 0.4, 0.6, 0.8, 0.9] {
        let mut d = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
        d.fill_relu_sparse(&mut rng, s);
        let mut skip = 0.0;
        let r = group.bench(&format!("sparse fwd s={s:.1}"), || {
            y.fill_zero();
            let mut st = KernelStats::new();
            sparse_fwd::fwd(&cfg, &d, &g, &mut y, SkipMode::MaskLoop, &mut st);
            skip = st.skip_fraction();
            black_box(&y);
        });
        tab.row_strings(vec![
            format!("{:.0}%", s * 100.0),
            format!("{:.2}", base / r.ns()),
            format!("{skip:.2}"),
        ]);
    }
    tab.print();
}

fn main() {
    let m = Machine::skylake_x();
    println!("sparsity grid: {SPARSITY_GRID:?}");
    let (_rows, fig, tab) = fig1_table4(&m);
    fig.print();
    tab.print();
    host_mode();
}
