//! Regenerates **Figure 4** and **Table 6**: end-to-end projected conv
//! execution time during training for VGG16 / ResNet-34 / ResNet-50 /
//! Fixup ResNet-50, normalized to `direct`, under the SparseTrain,
//! win/1x1 and combined policies (profiled-sparsity trajectories, 100
//! epochs).

use sparsetrain::bench::experiments::{dynamic_vs_static, fig4_table6, machine_with_threads};
use sparsetrain::coordinator::selector::AlgoPolicy;
use sparsetrain::nets::zoo::Network;
use sparsetrain::sim::Machine;
use sparsetrain::util::cli::Args;

fn main() {
    // cargo appends `--bench` when invoking harness=false bench binaries;
    // accept and ignore it.
    let args = Args::from_env(&["threads", "epochs"], &["bench"]).unwrap_or_else(|e| {
        eprintln!("error: {e}");
        std::process::exit(2);
    });
    let base = Machine::skylake_x();
    let threads = args.get_usize("threads", base.cores).unwrap_or_else(|e| {
        eprintln!("error: {e}");
        std::process::exit(2);
    });
    let epochs = args.get_usize("epochs", 100).unwrap_or_else(|e| {
        eprintln!("error: {e}");
        std::process::exit(2);
    });
    let m = machine_with_threads(&base, threads);
    println!("modeling {} active cores (--threads), {epochs} epochs", m.cores);
    let (projections, fig, tab) = fig4_table6(&m, epochs);
    fig.print();
    tab.print();

    // §5.3 extension: dynamic per-epoch algorithm selection vs the static
    // combined policy (FWD, all non-initial layers).
    println!("\n== dynamic vs static combined (FWD, {epochs} epochs) ==");
    for net in Network::ALL {
        let (_, _, gain) = dynamic_vs_static(&m, net, epochs);
        println!("  {:<16} dynamic/static speedup: {gain:.3}x", net.name());
    }

    // paper-shape assertions (E8)
    for p in &projections {
        let st = p.speedup_excl_first(AlgoPolicy::SparseTrainOnly);
        let comb = p.speedup_excl_first(AlgoPolicy::Combined);
        assert!(st > 1.0, "{}: SparseTrain must win ({st:.2})", p.network.name());
        assert!(
            comb >= st * 0.98,
            "{}: combined must be at least SparseTrain ({comb:.2} vs {st:.2})",
            p.network.name()
        );
    }
    let vgg = projections
        .iter()
        .find(|p| p.network.name() == "VGG16")
        .unwrap()
        .speedup_excl_first(AlgoPolicy::SparseTrainOnly);
    assert!(vgg > 1.8, "VGG16 should gain the most: {vgg:.2}");
    println!("fig4/table6 OK (projection assertions hold)");
}
