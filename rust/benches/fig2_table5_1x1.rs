//! Regenerates **Figure 2** and **Table 5**: speedup over `direct` on the
//! paper's 1×1 layers, vs `im2col` and the specialized `1x1` kernel.
//! Model mode over the full Table 2 1×1 configurations + host-mode
//! wallclock on a scaled layer (including the BWW asymmetry of §5.2).

use sparsetrain::bench::experiments::{fig2_table5, machine_with_threads};
use sparsetrain::bench::{black_box, BenchGroup};
use sparsetrain::kernels::{direct, onebyone, sparse_bww, sparse_fwd, ConvConfig, KernelStats, SkipMode};
use sparsetrain::sim::Machine;
use sparsetrain::tensor::{ActTensor, BatchTiledTensor, FilterTensor};
use sparsetrain::util::cli::Args;
use sparsetrain::util::prng::Xorshift;
use sparsetrain::util::table::Table;

fn host_mode() {
    let cfg = ConvConfig::square(16, 64, 64, 16, 1, 1);
    let mut rng = Xorshift::new(7);
    let mut g = FilterTensor::zeros(cfg.k, cfg.c, 1, 1);
    g.fill_uniform(&mut rng, -0.5, 0.5);
    let mut group = BenchGroup::new("host: 1x1 C=K=64 16x16 N=16 (scaled)");
    group.start();

    let mut y = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
    let mut d0 = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
    d0.fill_relu_sparse(&mut rng, 0.0);
    group.bench("direct FWD (dense)", || {
        y.fill_zero();
        let mut st = KernelStats::new();
        direct::fwd(&cfg, &d0, &g, &mut y, &mut st);
        black_box(&y);
    });
    group.bench("1x1 kernel FWD (dense)", || {
        y.fill_zero();
        let mut st = KernelStats::new();
        onebyone::fwd(&cfg, &d0, &g, &mut y, &mut st);
        black_box(&y);
    });

    let base = group.ns_of("direct FWD (dense)").unwrap();
    let mut tab = Table::new("host-measured 1x1 speedups vs direct")
        .header(&["sparsity", "FWD", "BWW"]);
    let mut dy = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
    dy.fill_uniform(&mut rng, -1.0, 1.0);
    // dense-direct BWW baseline
    let mut dg = FilterTensor::zeros(cfg.k, cfg.c, 1, 1);
    let d0t = BatchTiledTensor::from_act(&d0);
    group.bench("direct BWW (dense)", || {
        dg.fill_zero();
        let mut st = KernelStats::new();
        direct::bww(&cfg, &d0t, &dy, &mut dg, &mut st);
        black_box(&dg);
    });
    let base_bww = group.ns_of("direct BWW (dense)").unwrap();
    for s in [0.0, 0.4, 0.8] {
        let mut d = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
        d.fill_relu_sparse(&mut rng, s);
        let dt = BatchTiledTensor::from_act(&d);
        let rf = group.bench(&format!("sparse FWD s={s:.1}"), || {
            y.fill_zero();
            let mut st = KernelStats::new();
            sparse_fwd::fwd(&cfg, &d, &g, &mut y, SkipMode::MaskLoop, &mut st);
            black_box(&y);
        });
        let fwd_speedup = base / rf.ns();
        let rb = group.bench(&format!("sparse BWW s={s:.1}"), || {
            dg.fill_zero();
            let mut st = KernelStats::new();
            sparse_bww::bww(&cfg, &dt, &dy, &mut dg, SkipMode::MaskLoop, &mut st);
            black_box(&dg);
        });
        tab.row_strings(vec![
            format!("{:.0}%", s * 100.0),
            format!("{fwd_speedup:.2}"),
            format!("{:.2}", base_bww / rb.ns()),
        ]);
    }
    tab.print();
}

fn main() {
    // cargo appends `--bench` when invoking harness=false bench binaries;
    // accept and ignore it.
    let args = Args::from_env(&["threads"], &["bench"]).unwrap_or_else(|e| {
        eprintln!("error: {e}");
        std::process::exit(2);
    });
    let base = Machine::skylake_x();
    let threads = args.get_usize("threads", base.cores).unwrap_or_else(|e| {
        eprintln!("error: {e}");
        std::process::exit(2);
    });
    let m = machine_with_threads(&base, threads);
    println!("modeling {} active cores (--threads)", m.cores);
    let (_rows, fig, tab) = fig2_table5(&m);
    fig.print();
    tab.print();
    host_mode();
}
