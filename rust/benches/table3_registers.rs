//! Regenerates **Table 3** (optimal Q / T / pipelining per filter width at
//! K = 256, V = 16) and runs the §6 ablations as host benchmarks:
//! * Q tiling: Table-3 optimum vs naïve Q = K;
//! * zero-check style: mask loop (Alg. 3) vs per-lane branches (Alg. 2)
//!   vs dense (no checks).

use sparsetrain::bench::{black_box, BenchGroup};
use sparsetrain::kernels::regalloc::{plan_bww, plan_fwd, unroll_factor, REG_BUDGET};
use sparsetrain::kernels::{sparse_fwd, ConvConfig, KernelStats, SkipMode};
use sparsetrain::sim::branch::mispredicts_per_check;
use sparsetrain::tensor::{ActTensor, FilterTensor};
use sparsetrain::util::prng::Xorshift;
use sparsetrain::util::table::Table;

fn table3() {
    let mut tab = Table::new("Table 3: optimal setup for K=256, V=16")
        .header(&["R", "Q", "T", "pipelined", "#registers", "unroll"]);
    for r in [1usize, 3, 5] {
        let p = plan_fwd(256, r);
        tab.row_strings(vec![
            r.to_string(),
            p.q.to_string(),
            p.t.to_string(),
            if p.pipelined { "Y" } else { "N" }.to_string(),
            p.registers.to_string(),
            unroll_factor(&p, r).to_string(),
        ]);
        assert!(p.registers <= REG_BUDGET);
    }
    tab.print();
    // paper's exact values
    assert_eq!(plan_fwd(256, 1).q, 128);
    assert_eq!(plan_fwd(256, 3).q, 128);
    assert_eq!(plan_fwd(256, 5).q, 64);
    let b = plan_bww(256, 3);
    println!("BWW plan (K=256, R=3): Q={} T={} (register-resident)\n", b.q, b.t);
}

fn skip_mode_ablation() {
    let cfg = ConvConfig::square(1, 64, 64, 32, 3, 1);
    let mut rng = Xorshift::new(99);
    let mut g = FilterTensor::zeros(cfg.k, cfg.c, 3, 3);
    g.fill_uniform(&mut rng, -0.5, 0.5);
    let mut y = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());

    let mut group = BenchGroup::new("ablation: zero-check style (host, s=0.5)");
    group.start();
    let mut d = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
    d.fill_relu_sparse(&mut rng, 0.5);
    let mut mispredict_table =
        Table::new("modeled mispredicts/check at s=0.5").header(&["mode", "mispredicts"]);
    for (name, mode) in [
        ("dense (no skip)", SkipMode::Dense),
        ("per-lane branch (Alg 2)", SkipMode::PerLaneBranch),
        ("mask loop (Alg 3)", SkipMode::MaskLoop),
    ] {
        let mut hist = vec![0u64; 17];
        group.bench(name, || {
            y.fill_zero();
            let mut st = KernelStats::new();
            sparse_fwd::fwd(&cfg, &d, &g, &mut y, mode, &mut st);
            hist = st.popcount_hist.clone();
            black_box(&y);
        });
        mispredict_table.row_strings(vec![
            name.to_string(),
            format!("{:.2}", mispredicts_per_check(&hist, mode)),
        ]);
    }
    mispredict_table.print();
    let lane = group.ns_of("per-lane branch (Alg 2)").unwrap();
    let mask = group.ns_of("mask loop (Alg 3)").unwrap();
    println!("host: mask loop vs per-lane branch: {:.2}x\n", lane / mask);
}

fn main() {
    table3();
    skip_mode_ablation();
    println!("table3 OK");
}
